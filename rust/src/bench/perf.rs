//! §Perf harness — `mpi-dnn-train perf`.
//!
//! Times representative simulator workloads and reports events/s + wall
//! milliseconds, seeding the repo's engine-throughput trajectory
//! (`BENCH_engine.json`).  Event *counts* are deterministic (the engine
//! is bit-reproducible); wall times vary with the host, which is why the
//! CI job that runs this is non-gating.
//!
//! Workloads:
//!  * `engine-churn` — pure event-core throughput: schedule-and-serve
//!    churn through the calendar bucket queue, no strategy logic.
//!  * `tracer-off` — gated serve churn with the span tracer detached:
//!    times the disabled branch at every traced chokepoint, gating the
//!    §Observability zero-overhead-when-off contract via `perf --check`.
//!  * `graph-replay` — one cached ring [`GraphTemplate`] replayed many
//!    times under the neutral overlay: the build-once/replay-many path
//!    every per-rank-skew iteration rides.
//!  * `sweep-serialized` — fig9-style Horovod iterations (neutral
//!    scenario → serialized `CommOp` replay), the path every figure
//!    sweep point takes.
//!  * `sweep-graph` — the same points under a straggler scenario, which
//!    routes onto per-rank `CommGraph` execution (~`world`× the events).
//!  * `sweep-dense` — the same model on a dense-node cluster (4 GPUs per
//!    node, 2 NIC rails): the placement-aware graph path, where
//!    co-located ranks queue on shared node ports and intra-node hops
//!    ride PCIe — tracks the placed `GraphResources` layout across PRs.
//!  * `overlap-sweep` — a streams × fusion-cycle grid (§Overlap): the
//!    stream-lane execution model where fusion buffers' graphs
//!    interleave instead of serializing on the comm thread — tracks the
//!    overlapped hot path across PRs.
//!  * `ps-fanin` — gRPC+MPI parameter-server iterations: the fan-in
//!    template path (cold build on the first pass, warm replays through
//!    the strategy's [`TemplateCache`] after), so all three strategy
//!    families appear in the bench file.
//!  * `ps-rpc-window` — gRPC PS iterations over a window × world grid
//!    (§Transports): shard exchanges launch through a bounded stream-lane
//!    RPC window instead of firing at readiness — tracks the windowed
//!    fan-in path (lane arrive/launch/done churn) across PRs.
//!  * `fault-sweep` — fault-injected Horovod iterations (§Robustness): a
//!    mid-iteration rank crash per point drives abort, timeout/backoff
//!    accounting and the elastic rebuild over world−1 — tracks the
//!    recovery runner's cost across PRs.
//!  * `campaign` — a sustained-failure Horovod training campaign
//!    (§Robustness): a seeded Poisson crash stream over many iterations
//!    with Young–Daly checkpointing, rollback-and-replay and elastic
//!    rejoin — tracks the campaign layer (crashed iterations, rejoin
//!    collectives, world-cache churn) across PRs.
//!
//! `run_scale_sweep` (the `perf scale-sweep` subcommand) pushes the
//! event core to fleet worlds — 256 → 16k ranks over ring, RHD and PS
//! fan-in — recording events/s plus peak template and engine-slab
//! memory per row (§Scale).  Symmetric worlds ride the shared
//! [`crate::comm::SymTemplate`] plans (O(steps) resident, not
//! O(world × steps)); the `scale-ring-full` row keeps the legacy
//! per-rank template path as the throughput/memory baseline the shared
//! plans are measured against.
//!
//! `check_against` diffs a fresh run against the committed
//! `BENCH_engine.json` baseline (schema v2, one section per mode):
//! event-count drift is reported informationally (counts are
//! deterministic), while events/s regressions beyond the band — fresh
//! rate below `band × baseline` — fail the check.  Wall times are
//! host-dependent, hence the generous default band and the non-gating
//! CI job.

use std::time::Instant;

use super::table::Table;
use crate::cluster::presets;
use crate::cluster::Placement;
use crate::comm::allreduce::{shadow_steps, Algo};
use crate::comm::commop::{steps_sig, CommOp, ResKind};
use crate::comm::graph::{
    ps_fanin_graph, ring_graph, sym_allreduce_plan, GraphOverlay, GraphResources, GraphTemplate,
    TemplateCache, TemplateKey,
};
use crate::comm::{MpiFlavor, MpiWorld};
use crate::models::mobilenet;
use crate::sim::{run_campaign, CampaignSpec, CheckpointPolicy, Engine, FaultPlan, SimTime};
use crate::strategies::{Horovod, PsStrategy, Scenario, Strategy, WorldSpec};
use crate::util::error::Result;
use crate::util::json::{arr, num, obj, s, Json};

/// `BENCH_engine.json` schema id: v2 keeps one section per mode (quick
/// runs no longer clobber full baselines) and adds the §Scale peak
/// template/slab memory fields.
pub const BENCH_SCHEMA: &str = "mpi-dnn-train/bench-engine/v2";

/// Default events/s regression band for [`check_against`]: a fresh rate
/// below `band × baseline` fails.  Wall clocks differ across hosts, so
/// the default is deliberately loose — it catches order-of-magnitude
/// slumps (a degraded queue, an accidental O(world) scan), not noise.
pub const DEFAULT_BAND: f64 = 0.25;

/// One timed workload: `events` is deterministic, `wall_ms` is not.
/// `template_bytes` / `slab_bytes` are the §Scale peak-memory figures
/// (0 = not measured for this workload).
#[derive(Debug, Clone)]
pub struct PerfWorkload {
    pub name: String,
    pub detail: String,
    pub runs: usize,
    pub events: u64,
    pub wall_ms: f64,
    pub template_bytes: usize,
    pub slab_bytes: usize,
}

impl PerfWorkload {
    pub fn events_per_sec(&self) -> f64 {
        self.events as f64 / (self.wall_ms / 1e3).max(1e-9)
    }
}

fn timed(name: &str, detail: String, runs: usize, body: impl FnOnce() -> u64) -> PerfWorkload {
    timed_mem(name, detail, runs, || (body(), 0, 0))
}

/// Like [`timed`] but the body also reports (template, slab) peak bytes.
fn timed_mem(
    name: &str,
    detail: String,
    runs: usize,
    body: impl FnOnce() -> (u64, usize, usize),
) -> PerfWorkload {
    let t0 = Instant::now();
    let (events, template_bytes, slab_bytes) = body();
    let wall_ms = t0.elapsed().as_secs_f64() * 1e3;
    PerfWorkload {
        name: name.to_string(),
        detail,
        runs,
        events,
        wall_ms,
        template_bytes,
        slab_bytes,
    }
}

/// Run every workload.  `quick` shrinks sizes for CI smoke runs.
pub fn run_perf(quick: bool) -> Result<Vec<PerfWorkload>> {
    let mut out = Vec::new();

    // --- 1. pure event-core churn --------------------------------------
    let n: u64 = if quick { 50_000 } else { 200_000 };
    let reps = if quick { 2 } else { 5 };
    out.push(timed(
        "engine-churn",
        format!("{n} timers + {n} FIFO serves per run"),
        reps,
        || {
            let mut events = 0u64;
            for _ in 0..reps {
                let mut e = Engine::new();
                let r = e.resource(10.0, SimTime::ZERO);
                for i in 0..n {
                    e.at(SimTime(i * 10), move |e| {
                        e.serve(r, 64.0, |_| {});
                    });
                }
                e.run();
                events += e.executed();
            }
            events
        },
    ));

    // --- 1b. tracer-off overhead guard ---------------------------------
    // Gated FIFO serves drive every traced chokepoint (serve, gate
    // acquire/release, event push) with the tracer DETACHED — the
    // disabled branch the §Observability overhead contract bounds.
    // `perf --check` gates its events/s band like any other workload.
    out.push(timed(
        "tracer-off",
        format!("{n} gated FIFO serves per run, tracer detached (overhead contract)"),
        reps,
        || {
            let mut events = 0u64;
            for _ in 0..reps {
                let mut e = Engine::new();
                let r = e.resource(10.0, SimTime::ZERO);
                let g = e.gate();
                for i in 0..n {
                    e.at(SimTime(i * 10), move |e| {
                        e.acquire(g, move |e| {
                            e.serve(r, 64.0, move |e| e.release(g));
                        });
                    });
                }
                e.run();
                events += e.executed();
            }
            events
        },
    ));

    // --- 2. cached-template graph replay -------------------------------
    let p = if quick { 16 } else { 32 };
    let replays = if quick { 20 } else { 100 };
    let bytes = 4usize << 20;
    let w = MpiWorld::new(MpiFlavor::Mvapich2GdrOpt, presets::ri2());
    let (_, mut ctx) = w.plan(bytes);
    let (_, steps) = shadow_steps(Algo::Ring, p, bytes / 4, &mut ctx);
    let template = GraphTemplate::new(ring_graph(p, &steps));
    let nodes = template.graph().len();
    let neutral = GraphOverlay::neutral();
    out.push(timed(
        "graph-replay",
        format!("ring p={p} ({nodes} nodes) × {replays} replays of one template"),
        replays,
        || {
            let mut events = 0u64;
            for _ in 0..replays {
                let mut e = Engine::new();
                let res = GraphResources::install(&mut e, p);
                template.execute(&mut e, res.mapper(), &neutral, Box::new(|_| {}));
                e.run();
                events += e.executed();
            }
            events
        },
    ));

    // --- 3/4. fig9-style strategy sweeps --------------------------------
    let worlds: &[usize] = if quick { &[16] } else { &[32, 64, 128] };
    let passes = if quick { 1 } else { 3 };
    let cluster = presets::piz_daint();
    let model = mobilenet::mobilenet_v1();
    let h = Horovod::mpi(MpiFlavor::CrayMpich);
    let sweep = |sc: &Scenario| -> Result<u64> {
        let mut events = 0u64;
        for _ in 0..passes {
            for &world in worlds {
                let ws = WorldSpec::new(cluster.clone(), model.clone(), world);
                events += h.iteration_in(&ws, sc)?.engine_events;
            }
        }
        Ok(events)
    };

    let neutral_sc = Scenario::default();
    let mut failed: Result<()> = Ok(());
    out.push(timed(
        "sweep-serialized",
        format!("Horovod-MPI MobileNet pizdaint@{worlds:?} × {passes} passes, neutral"),
        passes * worlds.len(),
        || match sweep(&neutral_sc) {
            Ok(ev) => ev,
            Err(e) => {
                failed = Err(e);
                0
            }
        },
    ));
    failed?;

    let straggler = Scenario::straggler(1, 1.5);
    let mut failed: Result<()> = Ok(());
    out.push(timed(
        "sweep-graph",
        format!(
            "Horovod-MPI MobileNet pizdaint@{worlds:?} × {passes} passes, straggler 1×1.5 \
             (per-rank CommGraph path)"
        ),
        passes * worlds.len(),
        || match sweep(&straggler) {
            Ok(ev) => ev,
            Err(e) => {
                failed = Err(e);
                0
            }
        },
    ));
    failed?;

    // --- 5. dense-node placement sweep ----------------------------------
    let mut dense = cluster.clone();
    dense.gpus_per_node = 4;
    dense.nic_rails = 2;
    let dense_sweep = || -> Result<u64> {
        let mut events = 0u64;
        for _ in 0..passes {
            for &world in worlds {
                let ws = WorldSpec::new(dense.clone(), model.clone(), world);
                // neutral scenario + dense placement routes onto the
                // placed graph path
                events += h.iteration_in(&ws, &Scenario::default())?.engine_events;
            }
        }
        Ok(events)
    };
    let mut failed: Result<()> = Ok(());
    out.push(timed(
        "sweep-dense",
        format!(
            "Horovod-MPI MobileNet pizdaint(4 GPUs/node, 2 rails)@{worlds:?} × {passes} \
             passes, neutral (placed CommGraph path)"
        ),
        passes * worlds.len(),
        || match dense_sweep() {
            Ok(ev) => ev,
            Err(e) => {
                failed = Err(e);
                0
            }
        },
    ));
    failed?;

    // --- 6. overlap sweep: streams × fusion-cycle grid ------------------
    let overlap_worlds: &[usize] = if quick { &[16] } else { &[32, 64] };
    let stream_counts = [1usize, 2, 4];
    let cycle_grid = [2_500.0f64, 5_000.0];
    let overlap_sweep = || -> Result<u64> {
        let mut events = 0u64;
        for _ in 0..passes {
            for &world in overlap_worlds {
                for &cycle_us in &cycle_grid {
                    let mut hv = h.clone();
                    hv.cycle_us = cycle_us;
                    for &s in &stream_counts {
                        let ws = WorldSpec::new(cluster.clone(), model.clone(), world);
                        events += hv.iteration_in(&ws, &Scenario::overlap(s))?.engine_events;
                    }
                }
            }
        }
        Ok(events)
    };
    let mut failed: Result<()> = Ok(());
    out.push(timed(
        "overlap-sweep",
        format!(
            "Horovod-MPI MobileNet pizdaint@{overlap_worlds:?} × streams {stream_counts:?} × \
             cycle {cycle_grid:?}us × {passes} passes (stream-lane interleaving; streams = 1 \
             is the serialized baseline)"
        ),
        passes * overlap_worlds.len() * stream_counts.len() * cycle_grid.len(),
        || match overlap_sweep() {
            Ok(ev) => ev,
            Err(e) => {
                failed = Err(e);
                0
            }
        },
    ));
    failed?;

    // --- 7. PS fan-in: cold template build + warm replays ---------------
    let ps_worlds: &[usize] = if quick { &[8] } else { &[8, 16, 32] };
    // at least two passes so the warm-replay path (cache hit → overlay
    // replay) is always part of the measurement, even in --quick
    let ps_passes = passes.max(2);
    let ps = PsStrategy::grpc_mpi();
    let ps_sweep = || -> Result<u64> {
        let mut events = 0u64;
        for _ in 0..ps_passes {
            for &world in ps_worlds {
                let ws = WorldSpec::new(cluster.clone(), model.clone(), world);
                events += ps.iteration_in(&ws, &Scenario::default())?.engine_events;
            }
        }
        Ok(events)
    };
    let mut failed: Result<()> = Ok(());
    out.push(timed(
        "ps-fanin",
        format!(
            "gRPC+MPI PS MobileNet pizdaint@{ps_worlds:?} × {ps_passes} passes (pass 1 \
             cold-builds the fan-in templates, later passes warm-replay)"
        ),
        ps_passes * ps_worlds.len(),
        || match ps_sweep() {
            Ok(ev) => ev,
            Err(e) => {
                failed = Err(e);
                0
            }
        },
    ));
    failed?;

    // --- 7b. bounded RPC window: lane-scheduled PS shard exchanges ------
    let win_worlds: &[usize] = if quick { &[8] } else { &[8, 16, 32] };
    let windows: &[usize] = if quick { &[1, 4] } else { &[1, 2, 4, 8] };
    let ps_grpc = PsStrategy::grpc();
    let win_sweep = || -> Result<u64> {
        let mut events = 0u64;
        for _ in 0..ps_passes {
            for &world in win_worlds {
                for &window in windows {
                    let ws = WorldSpec::new(cluster.clone(), model.clone(), world);
                    events +=
                        ps_grpc.iteration_in(&ws, &Scenario::windowed(window))?.engine_events;
                }
            }
        }
        Ok(events)
    };
    let mut failed: Result<()> = Ok(());
    out.push(timed(
        "ps-rpc-window",
        format!(
            "gRPC PS MobileNet pizdaint@{win_worlds:?} × windows {windows:?} × {ps_passes} \
             passes (shard exchanges on a bounded stream-lane RPC window)"
        ),
        ps_passes * win_worlds.len() * windows.len(),
        || match win_sweep() {
            Ok(ev) => ev,
            Err(e) => {
                failed = Err(e);
                0
            }
        },
    ));
    failed?;

    // --- 8. fault-injected recovery: abort + elastic rebuild ------------
    let fault_worlds: &[usize] = if quick { &[8] } else { &[16, 32] };
    let fault_sweep = || -> Result<u64> {
        let mut events = 0u64;
        for _ in 0..passes {
            for &world in fault_worlds {
                let ws = WorldSpec::new(cluster.clone(), model.clone(), world);
                // a mid-iteration crash: phase 1 runs to the abort, then
                // detect -> backoff -> rebuild -> phase 2 over world−1
                let sc = Scenario::with_fault(FaultPlan::crash(1, 500.0));
                events += h.iteration_in(&ws, &sc)?.engine_events;
            }
        }
        Ok(events)
    };
    let mut failed: Result<()> = Ok(());
    out.push(timed(
        "fault-sweep",
        format!(
            "Horovod-MPI MobileNet pizdaint@{fault_worlds:?} × {passes} passes, rank crash at \
             500us (abort + elastic rebuild over world−1)"
        ),
        passes * fault_worlds.len(),
        || match fault_sweep() {
            Ok(ev) => ev,
            Err(e) => {
                failed = Err(e);
                0
            }
        },
    ));
    failed?;

    // --- 9. sustained-failure campaign: ckpt + rollback + rejoin --------
    let campaign_iters = if quick { 24 } else { 60 };
    let campaign = || -> Result<u64> {
        let mut events = 0u64;
        for _ in 0..passes {
            let ws = WorldSpec::new(cluster.clone(), model.clone(), 8);
            let sc = Scenario {
                campaign: CampaignSpec {
                    iters: campaign_iters,
                    mtbf_us: 60_000.0,
                    seed: 7,
                    policy: CheckpointPolicy::YoungDaly,
                    ckpt_cost_us: 500.0,
                    repair_us: 10_000.0,
                },
                ..Scenario::default()
            };
            events += run_campaign(&h, &ws, &sc)?.engine_events;
        }
        Ok(events)
    };
    let mut failed: Result<()> = Ok(());
    out.push(timed(
        "campaign",
        format!(
            "Horovod-MPI MobileNet pizdaint@8, {campaign_iters}-iter campaign × {passes} \
             passes: Poisson crashes (MTBF 60ms/rank), Young-Daly checkpoints, elastic rejoin"
        ),
        passes,
        || match campaign() {
            Ok(ev) => ev,
            Err(e) => {
                failed = Err(e);
                0
            }
        },
    ));
    failed?;

    Ok(out)
}

/// The §Scale fleet sweep (`perf scale-sweep`): ring / RHD / PS fan-in
/// at 256 → 16k ranks.  Symmetric worlds run through the shared
/// [`crate::comm::SymTemplate`] plans; `scale-ring-full` keeps the
/// legacy per-rank template path at one mid-size world as the baseline
/// the shared plans' events/s and memory are compared against.  The
/// ring is capped at 4k ranks (O(world²) node executions); RHD and PS
/// cover the full span.
pub fn run_scale_sweep(quick: bool) -> Result<Vec<PerfWorkload>> {
    let worlds: &[usize] = if quick { &[256] } else { &[256, 1024, 4096, 16384] };
    let bytes = 4usize << 20;
    let w = MpiWorld::new(MpiFlavor::Mvapich2GdrOpt, presets::ri2());
    let cache = TemplateCache::default();
    let neutral = GraphOverlay::neutral();
    let mut out = Vec::new();

    let sym_row = |out: &mut Vec<PerfWorkload>, algo: Algo, tag: &str, p: usize, replays: usize| {
        let (_, mut ctx) = w.plan(bytes);
        let (_, steps) = shadow_steps(algo, p, bytes / 4, &mut ctx);
        let sig = steps_sig(&steps);
        let plan = cache.get_or_build_sym(TemplateKey::allreduce(algo, p, sig), || {
            sym_allreduce_plan(algo, p, &steps, Placement::one_per_node())
                .expect("trivial symmetric plan")
        });
        let nodes = plan.node_count();
        let template_bytes = plan.approx_bytes();
        out.push(timed_mem(
            &format!("scale-{tag}@{p}"),
            format!("shared symmetric {tag} plan, {nodes} nodes × {replays} replays"),
            replays,
            || {
                let mut events = 0u64;
                let mut slab = 0usize;
                for _ in 0..replays {
                    let mut e = Engine::new();
                    let res = GraphResources::install(&mut e, p);
                    plan.execute(&mut e, &res, &neutral, false, Box::new(|_| {}));
                    e.run();
                    events += e.executed();
                    slab = slab.max(e.approx_slab_bytes());
                }
                (events, template_bytes, slab)
            },
        ));
    };

    for &p in worlds {
        // ring: 2(p−1) steps → O(p²) node executions; 16k would be half
        // a billion nodes per replay, so the ring stops at 4k
        if p <= 4096 {
            let replays = if quick { 2 } else { (4096 / p).max(1) };
            sym_row(&mut out, Algo::Ring, "ring", p, replays);
        }
        // RHD: 2·log₂p steps — shallow enough to cover the full span
        let replays = if quick { 2 } else { (16384 / p).max(1) };
        sym_row(&mut out, Algo::Rhd, "rhd", p, replays);
    }

    // PS fan-in at every world: 2w+1 nodes through the generic planned
    // executor, cold build into the cache then warm replays
    for &p in worlds {
        let push_us = 12.0;
        let update_us = 3.0;
        let sig = vec![p as u64, push_us.to_bits(), update_us.to_bits()];
        let key = TemplateKey::ps_fanin(p, Placement::one_per_node(), sig);
        let template = cache.get_or_build(key, || {
            let (g, _) = ps_fanin_graph(
                p,
                0,
                |_| vec![CommOp::fixed(ResKind::Wire, push_us)],
                vec![CommOp::fixed(ResKind::CpuReduce, update_us)],
                |_| vec![CommOp::fixed(ResKind::Wire, push_us)],
            );
            g
        });
        let template_bytes = template.approx_bytes();
        let replays = if quick { 4 } else { 16 };
        out.push(timed_mem(
            &format!("scale-ps@{p}"),
            format!("PS fan-in template, {} nodes × {replays} replays", template.graph().len()),
            replays,
            || {
                let mut events = 0u64;
                let mut slab = 0usize;
                for _ in 0..replays {
                    let mut e = Engine::new();
                    let res = GraphResources::install(&mut e, p);
                    template.execute(&mut e, res.mapper(), &neutral, Box::new(|_| {}));
                    e.run();
                    events += e.executed();
                    slab = slab.max(e.approx_slab_bytes());
                }
                (events, template_bytes, slab)
            },
        ));
    }

    // legacy per-rank template at one mid-size world: the baseline row
    // the shared plans' ≥2× events/s and O(1)-in-world memory claims
    // are checked against
    let p = if quick { 256 } else { 1024 };
    let (_, mut ctx) = w.plan(bytes);
    let (_, steps) = shadow_steps(Algo::Ring, p, bytes / 4, &mut ctx);
    let template = GraphTemplate::new(ring_graph(p, &steps));
    let template_bytes = template.approx_bytes();
    let replays = 2;
    out.push(timed_mem(
        &format!("scale-ring-full@{p}"),
        format!(
            "legacy per-rank ring template, {} nodes × {replays} replays (baseline)",
            template.graph().len()
        ),
        replays,
        || {
            let mut events = 0u64;
            let mut slab = 0usize;
            for _ in 0..replays {
                let mut e = Engine::new();
                let res = GraphResources::install(&mut e, p);
                template.execute(&mut e, res.mapper(), &neutral, Box::new(|_| {}));
                e.run();
                events += e.executed();
                slab = slab.max(e.approx_slab_bytes());
            }
            (events, template_bytes, slab)
        },
    ));

    Ok(out)
}

/// FNV-1a 64-bit — the provenance checksum hash.  Self-contained (no
/// deps) and stable across platforms; collision resistance is not a
/// goal here, only detecting hand-edits and truncation.
pub fn fnv64(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x100_0000_01b3);
    }
    h
}

/// Short git revision for the provenance block; "unknown" outside a
/// work tree (or when git itself is unavailable).
fn git_rev() -> String {
    std::process::Command::new("git")
        .args(["rev-parse", "--short", "HEAD"])
        .output()
        .ok()
        .filter(|o| o.status.success())
        .map(|o| String::from_utf8_lossy(&o.stdout).trim().to_string())
        .filter(|s| !s.is_empty())
        .unwrap_or_else(|| "unknown".to_string())
}

fn workloads_json(workloads: &[PerfWorkload]) -> Json {
    // sorted by name: the committed artifact's diff stays stable when a
    // workload moves within the harness
    let mut workloads: Vec<&PerfWorkload> = workloads.iter().collect();
    workloads.sort_by(|a, b| a.name.cmp(&b.name));
    arr(workloads.iter().map(|w| {
        obj(vec![
            ("name", s(&w.name)),
            ("detail", s(&w.detail)),
            ("runs", num(w.runs as f64)),
            ("events", num(w.events as f64)),
            ("wall_ms", num(w.wall_ms)),
            ("events_per_sec", num(w.events_per_sec())),
            ("template_bytes", num(w.template_bytes as f64)),
            ("slab_bytes", num(w.slab_bytes as f64)),
        ])
    }))
}

/// The mode key a run's workloads file under in the v2 document:
/// standard vs scale-sweep runs × quick vs full sizing.  Each key owns
/// its own baseline section, so no run ever clobbers another's.
pub fn bench_mode(scale: bool, quick: bool) -> &'static str {
    match (scale, quick) {
        (false, true) => "quick",
        (false, false) => "full",
        (true, true) => "scale-quick",
        (true, false) => "scale-full",
    }
}

/// A fresh v2 `BENCH_engine.json` payload holding only this run's mode.
pub fn perf_json(workloads: &[PerfWorkload], mode: &str) -> Json {
    merge_bench(None, workloads, mode)
}

/// Build the v2 payload, replacing this run's mode section while
/// preserving every *other* mode from `existing` (a quick smoke run
/// must not clobber a committed full or scale baseline, and vice
/// versa).  A missing, invalid, or pre-v2 `existing` starts fresh.
///
/// Every payload carries a `provenance` block: the config hash (sorted
/// workload names of this run), the git revision the artifact was
/// produced at, and an FNV-1a checksum over the serialized `modes`
/// subtree.  [`check_against`] recomputes the checksum before diffing —
/// serialization is a fixed point under parse (compact form, BTreeMap
/// key order, shortest-round-trip numbers), so a hand-edited or
/// truncated baseline is rejected instead of silently diffed against.
pub fn merge_bench(existing: Option<&Json>, workloads: &[PerfWorkload], mode: &str) -> Json {
    use std::collections::BTreeMap;
    let mut modes: BTreeMap<String, Json> = match existing {
        Some(j) if j.get("schema").and_then(|v| v.as_str()) == Some(BENCH_SCHEMA) => {
            match j.get("modes") {
                Some(Json::Obj(m)) => m.clone(),
                _ => BTreeMap::new(),
            }
        }
        _ => BTreeMap::new(),
    };
    modes.insert(mode.to_string(), obj(vec![("workloads", workloads_json(workloads))]));
    let modes_json = Json::Obj(modes);
    let mut names: Vec<&str> = workloads.iter().map(|w| w.name.as_str()).collect();
    names.sort_unstable();
    let provenance = obj(vec![
        ("config", s(&format!("fnv64:{:016x}", fnv64(names.join(",").as_bytes())))),
        ("git_rev", s(&git_rev())),
        ("checksum", s(&format!("fnv64:{:016x}", fnv64(modes_json.to_string().as_bytes())))),
    ]);
    obj(vec![
        ("schema", s(BENCH_SCHEMA)),
        ("modes", modes_json),
        ("provenance", provenance),
    ])
}

/// Diff a fresh run against a committed baseline file (schema v2).
/// Event-count drift is informational — counts are deterministic, so a
/// delta is a real execution-model change worth a look.  Events/s is
/// *banded*: a fresh rate below `band × baseline` is a regression and
/// fails the check (wall clocks vary across hosts; the band absorbs
/// that).  A missing baseline, a pre-v2 schema, or an empty mode
/// section seeds the trajectory instead of failing.  A baseline row
/// carrying `"seed": true` is an *inventory* entry — the workload name
/// is pinned (so coverage drift shows up in the diff) but its numbers
/// start with the first real run; commit `perf --out` / `perf
/// scale-sweep --out` output over the seed rows to upgrade them to a
/// numeric baseline.
pub fn check_against(
    fresh: &[PerfWorkload],
    mode: &str,
    path: &std::path::Path,
    band: f64,
) -> Result<String> {
    use std::fmt::Write as _;
    let text = match std::fs::read_to_string(path) {
        Ok(t) => t,
        Err(_) => {
            return Ok(format!(
                "perf-check: no baseline at {} — this run seeds the trajectory",
                path.display()
            ))
        }
    };
    let json = Json::parse(&text)
        .map_err(|e| crate::anyhow!("perf-check: {} is not valid JSON: {e}", path.display()))?;
    if json.get("schema").and_then(|v| v.as_str()) != Some(BENCH_SCHEMA) {
        return Ok(format!(
            "perf-check: baseline {} predates {BENCH_SCHEMA} — this run seeds the v2 trajectory",
            path.display()
        ));
    }
    // provenance: recompute the checksum over the parsed `modes` subtree
    // (serialization is a parse fixed point) and refuse to diff against a
    // hand-edited or truncated baseline; a pre-provenance v2 file is
    // tolerated with a note
    let provenance_note = match json
        .get("provenance")
        .and_then(|p| p.get("checksum"))
        .and_then(|c| c.as_str())
    {
        Some(want) => {
            let got = match json.get("modes") {
                Some(m) => format!("fnv64:{:016x}", fnv64(m.to_string().as_bytes())),
                None => "fnv64:<no modes section>".to_string(),
            };
            crate::ensure!(
                got == want,
                "perf-check: baseline {} fails its provenance checksum (file says {want}, \
                 modes hash to {got}) — the artifact was edited or truncated after `perf \
                 --out` wrote it; regenerate it with `perf --out` / `perf scale-sweep --out`",
                path.display()
            );
            format!("  provenance checksum verified ({want})\n")
        }
        None => "  (no provenance block — pre-provenance baseline, checksum not verified)\n"
            .to_string(),
    };
    let base: &[Json] = json
        .get("modes")
        .and_then(|m| m.get(mode))
        .and_then(|m| m.get("workloads"))
        .and_then(|w| w.as_arr())
        .unwrap_or(&[]);
    if base.is_empty() {
        return Ok(format!(
            "perf-check: baseline {} has no `{mode}` workloads yet — this run seeds the \
             trajectory",
            path.display()
        ));
    }
    let base_of =
        |name: &str| base.iter().find(|w| w.get("name").and_then(|n| n.as_str()) == Some(name));
    let mut out = format!("perf-check vs {} ({mode} mode, band {band:.2}):\n", path.display());
    out.push_str(&provenance_note);
    let mut regressions: Vec<String> = Vec::new();
    for w in fresh {
        let Some(b) = base_of(&w.name) else {
            let _ = writeln!(out, "  {:<20} NEW workload ({} events)", w.name, w.events);
            continue;
        };
        if b.get("seed").and_then(|v| v.as_bool()).unwrap_or(false) {
            let _ = writeln!(
                out,
                "  {:<20} inventory seed — {} events, {:.0} events/s start the trajectory",
                w.name,
                w.events,
                w.events_per_sec()
            );
            continue;
        }
        let b_events = b.get("events").and_then(|v| v.as_f64()).unwrap_or(0.0) as u64;
        let b_eps = b.get("events_per_sec").and_then(|v| v.as_f64()).unwrap_or(0.0);
        let f_eps = w.events_per_sec();
        let rate = if b_eps > 0.0 {
            format!("events/s {:.0} vs baseline {:.0} (×{:.2})", f_eps, b_eps, f_eps / b_eps)
        } else {
            format!("events/s {f_eps:.0} (no baseline rate)")
        };
        if b_events == w.events {
            let _ = writeln!(out, "  {:<20} events unchanged ({}); {rate}", w.name, w.events);
        } else {
            let delta = 100.0 * (w.events as f64 - b_events as f64) / (b_events as f64).max(1.0);
            let _ = writeln!(
                out,
                "  {:<20} events {} vs baseline {} ({delta:+.1}%) — deterministic drift, \
                 review the execution-model change; {rate}",
                w.name, w.events, b_events
            );
        }
        if b_eps > 0.0 && f_eps < band * b_eps {
            regressions
                .push(format!("{}: {f_eps:.0} events/s < {band:.2} × baseline {b_eps:.0}", w.name));
            let _ = writeln!(out, "  {:<20} REGRESSION below the events/s band", w.name);
        }
    }
    for b in base {
        if let Some(name) = b.get("name").and_then(|n| n.as_str()) {
            if !fresh.iter().any(|w| w.name == name) {
                let _ = writeln!(out, "  {name:<20} REMOVED (present only in the baseline)");
            }
        }
    }
    if !regressions.is_empty() {
        return Err(crate::anyhow!(
            "perf-check: events/s regression beyond band {band:.2}:\n  {}\n{out}",
            regressions.join("\n  ")
        ));
    }
    Ok(out)
}

fn fmt_bytes(b: usize) -> String {
    if b == 0 {
        "-".to_string()
    } else if b >= 1 << 20 {
        format!("{:.1}M", b as f64 / (1 << 20) as f64)
    } else if b >= 1 << 10 {
        format!("{:.1}K", b as f64 / (1 << 10) as f64)
    } else {
        format!("{b}")
    }
}

/// Render the workloads as the CLI table.
pub fn perf_table(workloads: &[PerfWorkload], quick: bool) -> Table {
    let title = if quick {
        "Perf harness (quick): simulator throughput"
    } else {
        "Perf harness: simulator throughput"
    };
    let mut t = Table::new(
        title,
        &["workload", "runs", "events", "wall ms", "events/s", "tmpl B", "slab B"],
    );
    for w in workloads {
        t.row([
            w.name.clone(),
            w.runs.to_string(),
            w.events.to_string(),
            format!("{:.1}", w.wall_ms),
            format!("{:.0}", w.events_per_sec()),
            fmt_bytes(w.template_bytes),
            fmt_bytes(w.slab_bytes),
        ]);
    }
    for w in workloads {
        t.note(format!("{}: {}", w.name, w.detail));
    }
    t.note("event counts are deterministic; wall times vary with the host (non-gating in CI)");
    t
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn quick_perf_produces_all_workloads_with_events() {
        let ws = run_perf(true).unwrap();
        assert_eq!(ws.len(), 11);
        for w in &ws {
            assert!(w.events > 0, "{}: no events", w.name);
            assert!(w.events_per_sec() > 0.0, "{}: zero rate", w.name);
        }
        // the graph path must schedule far more events than the
        // serialized path on the same sweep points
        let serialized = ws.iter().find(|w| w.name == "sweep-serialized").unwrap();
        let graph = ws.iter().find(|w| w.name == "sweep-graph").unwrap();
        assert!(
            graph.events > 2 * serialized.events,
            "graph sweep {} should dwarf serialized {}",
            graph.events,
            serialized.events
        );
        // the dense point rides the per-rank graph path too
        let dense = ws.iter().find(|w| w.name == "sweep-dense").unwrap();
        assert!(
            dense.events > 2 * serialized.events,
            "dense sweep {} should dwarf serialized {}",
            dense.events,
            serialized.events
        );
        // the overlap grid mixes serialized (streams = 1) and graph-path
        // (streams > 1) points, so it must out-event the serialized sweep
        let overlap = ws.iter().find(|w| w.name == "overlap-sweep").unwrap();
        assert!(
            overlap.events > serialized.events,
            "overlap sweep {} should exceed serialized {}",
            overlap.events,
            serialized.events
        );
        // the third strategy family is on the board
        assert!(ws.iter().any(|w| w.name == "ps-fanin"));
        // the bounded-RPC-window grid is on the board, and the window=1
        // points drive the lane machinery (extra arrive/launch events)
        assert!(ws.iter().any(|w| w.name == "ps-rpc-window"));
        // the overhead-contract guard is on the board
        assert!(ws.iter().any(|w| w.name == "tracer-off"));
        // the recovery runner is on the board
        let fault = ws.iter().find(|w| w.name == "fault-sweep").unwrap();
        assert!(fault.events > 0, "fault sweep scheduled no events");
        // the sustained-failure campaign layer is on the board, and its
        // crashed/rejoin iterations run real engine events
        let campaign = ws.iter().find(|w| w.name == "campaign").unwrap();
        assert!(campaign.events > 0, "campaign scheduled no events");
        let t = perf_table(&ws, true);
        assert_eq!(t.rows.len(), 11);
        let j = perf_json(&ws, "quick");
        assert_eq!(j.get("schema").and_then(|v| v.as_str()), Some(BENCH_SCHEMA));
        let quick_rows = j
            .get("modes")
            .and_then(|m| m.get("quick"))
            .and_then(|m| m.get("workloads"))
            .and_then(|w| w.as_arr())
            .map(|a| a.len());
        assert_eq!(quick_rows, Some(11));
    }

    #[test]
    fn scale_sweep_quick_reports_throughput_and_memory() {
        let ws = run_scale_sweep(true).unwrap();
        let names: Vec<&str> = ws.iter().map(|w| w.name.as_str()).collect();
        assert_eq!(
            names,
            ["scale-ring@256", "scale-rhd@256", "scale-ps@256", "scale-ring-full@256"]
        );
        for w in &ws {
            assert!(w.events > 0, "{}: no events", w.name);
            assert!(w.template_bytes > 0, "{}: no template bytes", w.name);
            assert!(w.slab_bytes > 0, "{}: no slab bytes", w.name);
        }
        // the whole point of the shared plans: O(steps) resident vs the
        // full template's O(world × steps) at the same world/costs
        let sym = ws.iter().find(|w| w.name == "scale-ring@256").unwrap();
        let full = ws.iter().find(|w| w.name == "scale-ring-full@256").unwrap();
        assert!(
            sym.template_bytes * 100 < full.template_bytes,
            "shared plan {} B should be ≪ full template {} B",
            sym.template_bytes,
            full.template_bytes
        );
        // per replay the two paths run the same programs on the same
        // resources; only launch plumbing differs (the sym path releases
        // all sources through one event, the full path one per source)
        let per_sym = sym.events / sym.runs as u64;
        let per_full = full.events / full.runs as u64;
        assert!(
            per_sym.abs_diff(per_full) as f64 <= 0.01 * per_full as f64,
            "sym {per_sym} vs full {per_full} events per replay"
        );
    }

    #[test]
    fn merge_bench_preserves_the_other_mode() {
        let mk = |name: &str| PerfWorkload {
            name: name.into(),
            detail: String::new(),
            runs: 1,
            events: 10,
            wall_ms: 1.0,
            template_bytes: 0,
            slab_bytes: 0,
        };
        let quick_doc = merge_bench(None, &[mk("a")], "quick");
        assert!(quick_doc.get("modes").and_then(|m| m.get("quick")).is_some());
        assert!(quick_doc.get("modes").and_then(|m| m.get("full")).is_none());
        // a full run on top keeps the quick section
        let both = merge_bench(Some(&quick_doc), &[mk("b")], "full");
        for mode in ["quick", "full"] {
            assert!(both.get("modes").and_then(|m| m.get(mode)).is_some(), "missing {mode}");
        }
        // re-running quick replaces quick but keeps full
        let again = merge_bench(Some(&both), &[mk("c")], "quick");
        let name_of = |j: &Json, mode: &str| {
            j.get("modes")
                .and_then(|m| m.get(mode))
                .and_then(|m| m.get("workloads"))
                .and_then(|w| w.as_arr())
                .and_then(|a| a[0].get("name").and_then(|n| n.as_str()).map(String::from))
        };
        assert_eq!(name_of(&again, "quick").as_deref(), Some("c"));
        assert_eq!(name_of(&again, "full").as_deref(), Some("b"));
        // scale modes are their own sections — a sweep never clobbers
        // the standard rows
        let with_scale = merge_bench(Some(&again), &[mk("e")], "scale-quick");
        assert_eq!(name_of(&with_scale, "quick").as_deref(), Some("c"));
        assert_eq!(name_of(&with_scale, "scale-quick").as_deref(), Some("e"));
        // a v1 document is not merged from — fresh start
        let v1 = obj(vec![("schema", s("mpi-dnn-train/bench-engine/v1"))]);
        let fresh = merge_bench(Some(&v1), &[mk("d")], "quick");
        assert!(fresh.get("modes").and_then(|m| m.get("full")).is_none());
    }

    #[test]
    fn check_against_seeds_bands_and_reports_drift() {
        let mk = |name: &str, events: u64, wall_ms: f64| PerfWorkload {
            name: name.into(),
            detail: String::new(),
            runs: 1,
            events,
            wall_ms,
            template_bytes: 0,
            slab_bytes: 0,
        };
        let dir = std::env::temp_dir().join("mpi-dnn-train-perf-check-test");
        std::fs::create_dir_all(&dir).unwrap();

        // missing baseline seeds the trajectory
        let missing = dir.join("does-not-exist.json");
        let r = check_against(&[mk("a", 10, 1.0)], "quick", &missing, DEFAULT_BAND).unwrap();
        assert!(r.contains("seeds the trajectory"), "{r}");

        // the committed v2 seed (empty modes) also seeds
        let empty = dir.join("empty.json");
        std::fs::write(&empty, perf_json(&[], "quick").to_string()).unwrap();
        let r = check_against(&[mk("a", 10, 1.0)], "quick", &empty, DEFAULT_BAND).unwrap();
        assert!(r.contains("no `quick` workloads yet"), "{r}");

        // a pre-v2 baseline seeds instead of mis-diffing
        let v1 = dir.join("v1.json");
        std::fs::write(&v1, "{\"schema\": \"mpi-dnn-train/bench-engine/v1\"}").unwrap();
        let r = check_against(&[mk("a", 10, 1.0)], "quick", &v1, DEFAULT_BAND).unwrap();
        assert!(r.contains("seeds the v2 trajectory"), "{r}");

        // populated baseline: unchanged, drifted, new and removed rows
        let base = dir.join("base.json");
        let baseline = perf_json(
            &[mk("same", 100, 1.0), mk("drift", 100, 1.0), mk("gone", 5, 1.0)],
            "quick",
        );
        std::fs::write(&base, baseline.to_string()).unwrap();
        let fresh = [mk("same", 100, 1.0), mk("drift", 110, 1.0), mk("new", 7, 1.0)];
        let r = check_against(&fresh, "quick", &base, DEFAULT_BAND).unwrap();
        assert!(r.contains("same") && r.contains("unchanged"), "{r}");
        assert!(r.contains("drift") && r.contains("+10.0%"), "{r}");
        assert!(r.contains("NEW workload"), "{r}");
        assert!(r.contains("REMOVED"), "{r}");

        // within the band: 2× slower passes under the default 0.25 band
        let r = check_against(&[mk("same", 100, 2.0)], "quick", &base, DEFAULT_BAND).unwrap();
        assert!(!r.contains("REGRESSION"), "{r}");

        // beyond the band: 100× slower fails
        let err = check_against(&[mk("same", 100, 100.0)], "quick", &base, DEFAULT_BAND);
        let msg = format!("{:#}", err.unwrap_err());
        assert!(msg.contains("regression beyond band"), "{msg}");

        // the band is caller-tunable: a strict 0.99 band flags 2× slower
        let err = check_against(&[mk("same", 100, 2.0)], "quick", &base, 0.99);
        assert!(err.is_err());

        // quick baselines never gate a full run (separate mode sections)
        let r = check_against(&[mk("same", 999, 100.0)], "full", &base, DEFAULT_BAND).unwrap();
        assert!(r.contains("no `full` workloads yet"), "{r}");

        // mode names from the CLI axes
        assert_eq!(bench_mode(false, true), "quick");
        assert_eq!(bench_mode(true, false), "scale-full");

        // an inventory seed row pins the name without gating numbers:
        // neither drift nor band applies, and coverage still diffs
        let seeded = dir.join("seeded.json");
        let seed_row = |name: &str| obj(vec![("name", s(name)), ("seed", Json::Bool(true))]);
        let rows = arr([seed_row("same"), seed_row("gone")]);
        let quick = obj(vec![("workloads", rows)]);
        let doc = obj(vec![
            ("schema", s(BENCH_SCHEMA)),
            ("modes", obj(vec![("quick", quick)])),
        ]);
        std::fs::write(&seeded, doc.to_string()).unwrap();
        let r = check_against(&[mk("same", 100, 100.0)], "quick", &seeded, 0.99).unwrap();
        assert!(r.contains("inventory seed"), "{r}");
        assert!(r.contains("REMOVED"), "{r}");
    }

    #[test]
    fn provenance_checksum_round_trips_and_rejects_tampering() {
        let mk = |name: &str, events: u64| PerfWorkload {
            name: name.into(),
            detail: "d".into(),
            runs: 1,
            events,
            wall_ms: 1.5,
            template_bytes: 3,
            slab_bytes: 4,
        };
        let dir = std::env::temp_dir().join("mpi-dnn-train-perf-provenance-test");
        std::fs::create_dir_all(&dir).unwrap();

        // every payload carries the block, and serialize -> parse ->
        // re-serialize reproduces the checksummed bytes exactly
        let doc = merge_bench(None, &[mk("b", 100), mk("a", 50)], "quick");
        let prov = doc.get("provenance").expect("provenance block");
        let want = prov.get("checksum").and_then(|c| c.as_str()).unwrap().to_string();
        assert!(want.starts_with("fnv64:") && want.len() == "fnv64:".len() + 16, "{want}");
        assert!(prov.get("git_rev").and_then(|g| g.as_str()).is_some());
        let reparsed = Json::parse(&doc.to_string()).unwrap();
        let modes = reparsed.get("modes").unwrap();
        assert_eq!(format!("fnv64:{:016x}", fnv64(modes.to_string().as_bytes())), want);

        // workloads serialize name-sorted regardless of run order
        let names: Vec<String> = reparsed
            .get("modes")
            .and_then(|m| m.get("quick"))
            .and_then(|m| m.get("workloads"))
            .and_then(|w| w.as_arr())
            .unwrap()
            .iter()
            .filter_map(|w| w.get("name").and_then(|n| n.as_str()).map(String::from))
            .collect();
        assert_eq!(names, ["a", "b"]);

        // an intact artifact passes the check (numbers match themselves)
        let path = dir.join("intact.json");
        std::fs::write(&path, doc.to_string()).unwrap();
        let r = check_against(&[mk("b", 100), mk("a", 50)], "quick", &path, DEFAULT_BAND)
            .unwrap();
        assert!(r.contains("provenance checksum verified"), "{r}");

        // hand-editing a number invalidates the checksum and fails loudly
        let tampered = doc.to_string().replace("\"events\":100", "\"events\":101");
        assert_ne!(tampered, doc.to_string(), "tamper target must exist");
        std::fs::write(&path, tampered).unwrap();
        let err = check_against(&[mk("b", 100), mk("a", 50)], "quick", &path, DEFAULT_BAND);
        let msg = format!("{:#}", err.unwrap_err());
        assert!(msg.contains("provenance checksum"), "{msg}");

        // a provenance-free v2 baseline (the committed seed document) is
        // tolerated with a note, not rejected
        let bare = obj(vec![
            ("schema", s(BENCH_SCHEMA)),
            (
                "modes",
                obj(vec![(
                    "quick",
                    obj(vec![("workloads", arr([obj(vec![("name", s("b")), ("seed", Json::Bool(true))])]))]),
                )]),
            ),
        ]);
        std::fs::write(&path, bare.to_string()).unwrap();
        let r = check_against(&[mk("b", 100)], "quick", &path, DEFAULT_BAND).unwrap();
        assert!(r.contains("checksum not verified"), "{r}");

        // fnv64 is the standard FNV-1a 64 vector set
        assert_eq!(fnv64(b""), 0xcbf2_9ce4_8422_2325);
        assert_eq!(fnv64(b"a"), 0xaf63_dc4c_8601_ec8c);
    }

    #[test]
    fn event_counts_are_deterministic() {
        let a = run_perf(true).unwrap();
        let b = run_perf(true).unwrap();
        let ev = |v: &[PerfWorkload]| v.iter().map(|w| w.events).collect::<Vec<_>>();
        assert_eq!(ev(&a), ev(&b));
    }
}
