//! Aligned-text + JSON table rendering for the figure harness.

use std::fmt;

use crate::util::json::{arr, obj, s, Json};

#[derive(Debug, Clone)]
pub struct Table {
    pub title: String,
    pub headers: Vec<String>,
    pub rows: Vec<Vec<String>>,
    /// Free-form notes printed under the table (paper-vs-measured remarks).
    pub notes: Vec<String>,
}

impl Table {
    pub fn new(title: &str, headers: &[&str]) -> Table {
        Table {
            title: title.to_string(),
            headers: headers.iter().map(|h| h.to_string()).collect(),
            rows: Vec::new(),
            notes: Vec::new(),
        }
    }

    pub fn row<I: IntoIterator<Item = String>>(&mut self, cells: I) {
        let row: Vec<String> = cells.into_iter().collect();
        assert_eq!(row.len(), self.headers.len(), "row arity mismatch in `{}`", self.title);
        self.rows.push(row);
    }

    pub fn note(&mut self, n: impl Into<String>) {
        self.notes.push(n.into());
    }

    pub fn to_json(&self) -> Json {
        obj(vec![
            ("title", s(&self.title)),
            ("headers", arr(self.headers.iter().map(|h| s(h)))),
            (
                "rows",
                arr(self.rows.iter().map(|r| arr(r.iter().map(|c| s(c))))),
            ),
            ("notes", arr(self.notes.iter().map(|n| s(n)))),
        ])
    }
}

impl fmt::Display for Table {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        writeln!(f, "== {} ==", self.title)?;
        let line = |f: &mut fmt::Formatter<'_>, cells: &[String]| -> fmt::Result {
            for (i, c) in cells.iter().enumerate() {
                write!(f, "{:>w$}  ", c, w = widths[i])?;
            }
            writeln!(f)
        };
        line(f, &self.headers)?;
        let total: usize = widths.iter().sum::<usize>() + 2 * widths.len();
        writeln!(f, "{}", "-".repeat(total))?;
        for row in &self.rows {
            line(f, row)?;
        }
        for n in &self.notes {
            writeln!(f, "  note: {n}")?;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = Table::new("demo", &["a", "long_header"]);
        t.row(["1".into(), "2".into()]);
        t.row(["100".into(), "x".into()]);
        t.note("hello");
        let out = t.to_string();
        assert!(out.contains("demo"));
        assert!(out.contains("long_header"));
        assert!(out.contains("note: hello"));
    }

    #[test]
    #[should_panic(expected = "arity")]
    fn arity_checked() {
        let mut t = Table::new("x", &["a", "b"]);
        t.row(["only-one".into()]);
    }

    #[test]
    fn json_shape() {
        let mut t = Table::new("j", &["h"]);
        t.row(["v".into()]);
        let j = t.to_json().to_string();
        assert!(j.contains("\"title\":\"j\""));
        assert!(j.contains("\"rows\":[[\"v\"]]"));
    }
}
