//! One generator per paper figure (DESIGN.md §4's experiment index).
//! Each returns the same rows/series the paper plots; EXPERIMENTS.md
//! records paper-vs-measured for the headline numbers.

use crate::util::error::Result;

use super::table::Table;
use crate::cluster::{presets, GpuModel};
use crate::comm::nccl::NcclWorld;
use crate::comm::{MpiFlavor, MpiWorld};
use crate::models::{mobilenet, nasnet, resnet, ModelProfile};
use crate::strategies::{Baidu, Horovod, PsStrategy, Strategy, WorldSpec};
use crate::util::bytes::{fmt_bytes, fmt_us, msg_size_sweep};
use crate::util::par::par_map_ordered;

/// Figure 2: effect of batch size on single-GPU throughput for three GPU
/// generations (ResNet-50).
pub fn fig2() -> Table {
    let model = resnet::resnet50();
    let gpus = [GpuModel::k80(), GpuModel::p100(), GpuModel::v100()];
    let mut t = Table::new(
        "Fig 2: ResNet-50 img/s vs batch size (single GPU)",
        &["batch", "K80", "P100", "V100"],
    );
    for batch in [1usize, 2, 4, 8, 16, 32, 64, 128] {
        let mut row = vec![batch.to_string()];
        for gpu in &gpus {
            if gpu.batch_fits(model.act_bytes_per_sample, batch) {
                row.push(format!("{:.1}", model.throughput_1gpu(gpu, batch)));
            } else {
                row.push("OOM".into());
            }
        }
        t.row(row);
    }
    t.note("paper: sweet spot at 64 for all three generations; faster GPUs gain more from large batches");
    t
}

/// Figure 3: six distributed-training approaches, ResNet-50, RI2 ≤ 16.
pub fn fig3() -> Result<Table> {
    let cluster = presets::ri2();
    let model = resnet::resnet50();
    let strategies = crate::strategies::all_strategies();
    let mut headers = vec!["gpus".to_string(), "ideal".to_string()];
    headers.extend(strategies.iter().map(|s| s.name()));
    let mut t = Table::new(
        "Fig 3: ResNet-50 img/s by approach (RI2, K80 + IB EDR)",
        &headers.iter().map(|h| h.as_str()).collect::<Vec<_>>(),
    );
    let rows = par_map_ordered([1usize, 2, 4, 8, 16], |gpus| {
        let ws = WorldSpec::new(cluster.clone(), model.clone(), gpus);
        let ideal = gpus as f64 * ws.throughput_1gpu();
        let mut row = vec![gpus.to_string(), format!("{ideal:.0}")];
        for s in &strategies {
            row.push(match s.iteration(&ws) {
                Ok(r) => format!("{:.0}", r.imgs_per_sec),
                Err(_) => "n/a".into(),
            });
        }
        row
    });
    for row in rows {
        t.row(row);
    }
    t.note("paper insight 1: No-gRPC (Baidu/Horovod) > gRPC family for most configs");
    Ok(t)
}

/// Figure 4: MPI (stock MVAPICH2) vs NCCL2 Allreduce latency, RI2, 16 ranks.
pub fn fig4() -> Result<Table> {
    let cluster = presets::ri2();
    let mpi = MpiWorld::new(MpiFlavor::Mvapich2, cluster.clone());
    let nccl = NcclWorld::new(cluster)?;
    let mut t = Table::new(
        "Fig 4: Allreduce latency, 16 GPUs (RI2): MVAPICH2 vs NCCL2",
        &["size", "MPI (us)", "NCCL2 (us)", "NCCL2/MPI"],
    );
    for bytes in msg_size_sweep(256 << 20) {
        let m = mpi.allreduce_latency(16, bytes).time.as_us();
        let n = nccl.allreduce_latency(16, bytes).time.as_us();
        t.row([fmt_bytes(bytes), format!("{m:.1}"), format!("{n:.1}"), format!("{:.2}", n / m)]);
    }
    t.note("paper: NCCL2 wins at DL-relevant (large) sizes — motivates the MPI-Opt work");
    Ok(t)
}

/// Figure 6: MPI vs NCCL2 vs MPI-Opt (the paper's §V design).
pub fn fig6() -> Result<Table> {
    let cluster = presets::ri2();
    let mpi = MpiWorld::new(MpiFlavor::Mvapich2, cluster.clone());
    let opt = MpiWorld::new(MpiFlavor::Mvapich2GdrOpt, cluster.clone());
    let nccl = NcclWorld::new(cluster)?;
    let mut t = Table::new(
        "Fig 6: Allreduce latency, 16 GPUs (RI2): MPI vs NCCL2 vs MPI-Opt",
        &["size", "MPI", "NCCL2", "MPI-Opt", "MPI/Opt", "NCCL2/Opt"],
    );
    let mut small_ratio_max: f64 = 0.0;
    let mut large_ratio = 0.0;
    for bytes in msg_size_sweep(256 << 20) {
        let m = mpi.allreduce_latency(16, bytes).time.as_us();
        let n = nccl.allreduce_latency(16, bytes).time.as_us();
        let o = opt.allreduce_latency(16, bytes).time.as_us();
        if bytes <= 128 * 1024 {
            small_ratio_max = small_ratio_max.max(n / o);
        }
        if bytes == 256 << 20 {
            large_ratio = n / o;
        }
        t.row([
            fmt_bytes(bytes),
            fmt_us(m),
            fmt_us(n),
            fmt_us(o),
            format!("{:.1}x", m / o),
            format!("{:.1}x", n / o),
        ]);
    }
    t.note(format!(
        "H1 check — paper: MPI-Opt 5–17x vs NCCL2 (small/medium); measured max {small_ratio_max:.1}x"
    ));
    t.note(format!(
        "H2 check — paper: 29% latency reduction at large msgs; measured {:.0}% (256MB)",
        (1.0 - 1.0 / large_ratio) * 100.0
    ));
    Ok(t)
}

/// Figure 7: Horovod-NCCL vs -MPI vs -MPI-Opt, ResNet-50, RI2 ≤ 16.
pub fn fig7() -> Result<Table> {
    scaling_table(
        "Fig 7: ResNet-50 Horovod variants (RI2, ≤16 GPUs)",
        presets::ri2(),
        resnet::resnet50(),
        &[1, 2, 4, 8, 16],
        vec![
            Box::new(Horovod::nccl()),
            Box::new(Horovod::mpi(MpiFlavor::Mvapich2)),
            Box::new(Horovod::mpi(MpiFlavor::Mvapich2GdrOpt)),
        ],
        "paper: MPI-Opt ≥ NCCL ≈ 98% efficiency at 16 nodes",
    )
}

/// Figure 8: Horovod-NCCL vs -MPI-Opt, ResNet-50, Owens ≤ 64 P100s.
pub fn fig8() -> Result<Table> {
    scaling_table(
        "Fig 8: ResNet-50 Horovod-NCCL vs Horovod-MPI-Opt (Owens, ≤64 GPUs)",
        presets::owens(),
        resnet::resnet50(),
        &[1, 2, 4, 8, 16, 32, 64],
        vec![
            Box::new(Horovod::nccl()),
            Box::new(Horovod::mpi(MpiFlavor::Mvapich2GdrOpt)),
        ],
        "paper: ≈90% scaling efficiency at 64 GPUs (H3)",
    )
}

/// Figure 9: gRPC / gRPC+MPI / Baidu / Horovod-MPI on Piz Daint ≤ 128,
/// one sub-table per model.
pub fn fig9(model_name: &str) -> Result<Table> {
    let model: ModelProfile = match model_name {
        "nasnet" => nasnet::nasnet_large(),
        "resnet50" => resnet::resnet50(),
        "mobilenet" => mobilenet::mobilenet_v1(),
        other => crate::bail!("fig9 model must be nasnet|resnet50|mobilenet, got {other}"),
    };
    scaling_table(
        &format!("Fig 9: {} on Piz Daint (Cray Aries, ≤128 GPUs)", model.name),
        presets::piz_daint(),
        model,
        &[1, 2, 4, 8, 16, 32, 64, 128],
        vec![
            Box::new(PsStrategy::grpc()),
            Box::new(PsStrategy::grpc_mpi()),
            Box::new(PsStrategy::rdma()),
            Box::new(Baidu::with_flavor(MpiFlavor::CrayMpich)),
            Box::new(Horovod::mpi(MpiFlavor::CrayMpich)),
        ],
        "paper efficiencies @128 (Horovod-MPI): NASNet 92%, ResNet-50 71%, MobileNet 16%; \
         gRPC+MPI worst (single-threaded); Horovod 1.8x/3.2x over gRPC for ResNet/MobileNet (H4); \
         RDMA is the zero-copy PS upper bound (one-sided writes, no encode)",
    )
}

fn scaling_table(
    title: &str,
    cluster: crate::cluster::ClusterSpec,
    model: ModelProfile,
    gpu_counts: &[usize],
    strategies: Vec<Box<dyn Strategy>>,
    note: &str,
) -> Result<Table> {
    let mut headers = vec!["gpus".to_string(), "ideal".to_string()];
    for s in &strategies {
        headers.push(s.name());
        headers.push(format!("{} eff", s.name()));
    }
    let mut t =
        Table::new(title, &headers.iter().map(|h| h.as_str()).collect::<Vec<_>>());
    // Every sweep point owns its engine, so points fan out across threads;
    // joining in order keeps the table (and the emitted JSON) identical to
    // the sequential run.
    let rows = par_map_ordered(gpu_counts.iter().copied(), |gpus| {
        let ws = WorldSpec::new(cluster.clone(), model.clone(), gpus);
        let ideal = gpus as f64 * ws.throughput_1gpu();
        let mut row = vec![gpus.to_string(), format!("{ideal:.0}")];
        for s in &strategies {
            match s.iteration(&ws) {
                Ok(r) => {
                    row.push(format!("{:.0}", r.imgs_per_sec));
                    row.push(format!("{:.0}%", 100.0 * r.scaling_efficiency));
                }
                Err(_) => {
                    row.push("n/a".into());
                    row.push("-".into());
                }
            }
        }
        row
    });
    for row in rows {
        t.row(row);
    }
    t.note(note);
    Ok(t)
}

/// Ablation (DESIGN.md §4 "ablation benches"): Horovod fusion-threshold
/// sweep — the knob §III-C2 says "we experimentally determine".
pub fn ablation_fusion(cluster_name: &str, world: usize) -> Result<Table> {
    let cluster = presets::by_name(cluster_name)?;
    let model = resnet::resnet50();
    let mut t = Table::new(
        &format!("Ablation: Horovod tensor-fusion threshold (ResNet-50, {cluster_name}@{world})"),
        &["threshold", "img/s", "efficiency"],
    );
    for mb in [0.25f64, 1.0, 4.0, 16.0, 64.0, 256.0] {
        let mut h = Horovod::mpi(MpiFlavor::Mvapich2GdrOpt);
        h.fusion_bytes = (mb * 1024.0 * 1024.0) as usize;
        let ws = WorldSpec::new(cluster.clone(), model.clone(), world);
        let r = h.iteration(&ws)?;
        t.row([
            fmt_bytes(h.fusion_bytes),
            format!("{:.0}", r.imgs_per_sec),
            format!("{:.0}%", 100.0 * r.scaling_efficiency),
        ]);
    }
    t.note("fusion amortizes per-collective latency; oversize thresholds delay the pipeline");
    Ok(t)
}

/// Scenario comparison: every strategy under pristine vs perturbed
/// conditions on one (cluster, model, world) point — the table behind
/// `mpi-dnn-train scenario straggler|hetero|jitter|link-load`.
pub fn scenario_compare(
    title: &str,
    cluster: crate::cluster::ClusterSpec,
    model: ModelProfile,
    world: usize,
    sc: &crate::strategies::Scenario,
) -> Result<Table> {
    let ws = WorldSpec::new(cluster, model, world);
    let strategies = crate::strategies::all_strategies();
    let mut t = Table::new(
        title,
        &["strategy", "img/s", "img/s (scenario)", "slowdown", "eff", "eff (scenario)"],
    );
    let rows = par_map_ordered(strategies.iter(), |s| {
        // unavailable / failing strategies keep their row with "n/a"
        // cells, same convention as the figure sweeps
        match (s.iteration(&ws), s.iteration_in(&ws, sc)) {
            (Ok(base), Ok(pert)) => vec![
                s.name(),
                format!("{:.0}", base.imgs_per_sec),
                format!("{:.0}", pert.imgs_per_sec),
                format!("{:.2}x", pert.iter.as_us() / base.iter.as_us()),
                format!("{:.0}%", 100.0 * base.scaling_efficiency),
                format!("{:.0}%", 100.0 * pert.scaling_efficiency),
            ],
            _ => vec![
                s.name(),
                "n/a".into(),
                "n/a".into(),
                "-".into(),
                "-".into(),
                "-".into(),
            ],
        }
    });
    for row in rows {
        t.row(row);
    }
    t.note(format!("{sc:?}"));
    Ok(t)
}

/// The Horovod variant a cluster would actually run: MPI-Opt where the
/// fabric has GDR, Cray-MPICH otherwise (one place encodes this policy).
fn default_horovod(cluster: &crate::cluster::ClusterSpec) -> Horovod {
    if cluster.fabric.gdr {
        Horovod::mpi(MpiFlavor::Mvapich2GdrOpt)
    } else {
        Horovod::mpi(MpiFlavor::CrayMpich)
    }
}

/// The Baidu flavor a cluster would actually run: stock MVAPICH2 on the
/// IB clusters, Cray-MPICH on Piz Daint (mirrors `default_horovod`).
fn default_baidu(cluster: &crate::cluster::ClusterSpec) -> Baidu {
    if cluster.fabric.gdr {
        Baidu::new()
    } else {
        Baidu::with_flavor(MpiFlavor::CrayMpich)
    }
}

/// Two identical jobs sharing one fabric on the graph path — a Horovod
/// variant or Baidu's per-tensor rings (both jobs' per-rank graphs queue
/// on the same physical `(node, rail)` NIC ports via
/// `GraphResources::sharing_wire`), or a PS transport (shared per-server
/// NIC queues).
/// `family` is either a family name (`horovod` / `baidu` pick the
/// cluster's default variant, `ps` = gRPC) or a concrete strategy name
/// (`horovod-mpi-opt`, `grpc+verbs`, …) so the experiment launcher can
/// run the link-share with the exact strategy the config selected.
pub fn scenario_two_jobs(
    cluster: crate::cluster::ClusterSpec,
    model: ModelProfile,
    world: usize,
    offset_us: f64,
    family: &str,
) -> Result<Table> {
    use crate::sim::SimTime;
    use crate::strategies::scenario::{link_share, link_share_baidu, link_share_ps};
    let cluster_name = cluster.name;
    let ws = WorldSpec::new(cluster.clone(), model, world);
    let offset = SimTime::from_us(offset_us);
    let (label, r) = match family.to_ascii_lowercase().as_str() {
        "horovod" => {
            let h = default_horovod(&cluster);
            (h.name(), link_share(&h, &ws, offset)?)
        }
        "baidu" => {
            let b = default_baidu(&cluster);
            (b.name(), link_share_baidu(&b, &ws, offset)?)
        }
        // concrete names pin the exact flavor the config selected,
        // mirroring strategies::by_name
        "baidu-mpi" => {
            let b = Baidu::new();
            (b.name(), link_share_baidu(&b, &ws, offset)?)
        }
        "baidu-cray" => {
            let b = Baidu::with_flavor(MpiFlavor::CrayMpich);
            (b.name(), link_share_baidu(&b, &ws, offset)?)
        }
        "horovod-mpi" => {
            let h = Horovod::mpi(MpiFlavor::Mvapich2);
            (h.name(), link_share(&h, &ws, offset)?)
        }
        "horovod-mpi-opt" => {
            let h = Horovod::mpi(MpiFlavor::Mvapich2GdrOpt);
            (h.name(), link_share(&h, &ws, offset)?)
        }
        "horovod-cray" => {
            let h = Horovod::mpi(MpiFlavor::CrayMpich);
            (h.name(), link_share(&h, &ws, offset)?)
        }
        "horovod-nccl" => {
            let h = Horovod::nccl();
            (h.name(), link_share(&h, &ws, offset)?)
        }
        "ps" | "grpc" => {
            let ps = PsStrategy::grpc();
            (ps.name(), link_share_ps(&ps, &ws, offset)?)
        }
        "ps-mpi" | "grpc+mpi" | "grpc-mpi" => {
            let ps = PsStrategy::grpc_mpi();
            (ps.name(), link_share_ps(&ps, &ws, offset)?)
        }
        "ps-verbs" | "grpc+verbs" | "grpc-verbs" => {
            let ps = PsStrategy::grpc_verbs();
            (ps.name(), link_share_ps(&ps, &ws, offset)?)
        }
        "rdma" | "grpc+rdma" | "grpc-rdma" => {
            let ps = PsStrategy::rdma();
            (ps.name(), link_share_ps(&ps, &ws, offset)?)
        }
        other => crate::bail!(
            "two-jobs family must be horovod[-mpi|-mpi-opt|-cray|-nccl], baidu[-mpi|-cray], or \
             ps (grpc | grpc+mpi | grpc+verbs | rdma), got `{other}`"
        ),
    };
    let title = format!(
        "Scenario: two {world}-GPU {label} jobs sharing the {cluster_name} fabric (B offset {})",
        fmt_us(offset_us)
    );
    let [sa, sb] = r.slowdowns();
    let mut t = Table::new(&title, &["job", "iter", "slowdown vs solo"]);
    t.row(["solo".into(), format!("{}", r.solo_iter), "1.00x".into()]);
    t.row(["A".into(), format!("{}", r.job_iters[0]), format!("{sa:.2}x")]);
    t.row(["B".into(), format!("{}", r.job_iters[1]), format!("{sb:.2}x")]);
    t.note(format!(
        "shared wire: {} ops, {} busy — contention emerges from FIFO queueing, not a formula",
        r.wire_served, r.wire_busy
    ));
    Ok(t)
}

/// §Robustness comparison: every strategy running one fault-injected
/// iteration (crash, link flap, rail failure, straggler-death — whatever
/// the scenario's `FaultPlan` schedules) next to its fault-free baseline
/// — the table behind `mpi-dnn-train scenario fault`.  Goodput charges
/// the recovery gap *and* the lost work to the surviving world's step.
pub fn fault_compare(
    cluster: crate::cluster::ClusterSpec,
    model: ModelProfile,
    world: usize,
    sc: &crate::strategies::Scenario,
) -> Result<Table> {
    let cluster_name = cluster.name;
    let title = format!(
        "Scenario: injected faults ({}, {cluster_name}@{world})",
        model.name
    );
    let ws = WorldSpec::new(cluster, model, world);
    // fail loudly on an invalid plan instead of emitting an all-"n/a"
    // table (each strategy would reject it row by row)
    sc.fault.validate(ws.world, &ws.cluster.placement())?;
    let strategies = crate::strategies::all_strategies();
    let mut t = Table::new(
        &title,
        &[
            "strategy",
            "img/s",
            "goodput",
            "detect",
            "recover",
            "lost work",
            "retries",
            "world after",
        ],
    );
    let rows = par_map_ordered(strategies.iter(), |s| {
        // unavailable / failing strategies keep their row with "n/a"
        // cells, same convention as the figure sweeps
        match (s.iteration(&ws), s.iteration_in(&ws, sc)) {
            (Ok(base), Ok(pert)) => {
                let f = pert.fault.expect("non-empty fault plan attaches a FaultReport");
                vec![
                    s.name(),
                    format!("{:.0}", base.imgs_per_sec),
                    format!("{:.0}", f.goodput_imgs_per_sec),
                    format!("{}", f.detect),
                    format!("{}", f.recover),
                    format!("{}", f.lost_work),
                    f.retries.to_string(),
                    f.surviving_world.to_string(),
                ]
            }
            _ => {
                let mut row = vec![s.name(), "n/a".into(), "n/a".into()];
                row.extend(["-", "-", "-", "-", "-"].map(String::from));
                row
            }
        }
    });
    for row in rows {
        t.row(row);
    }
    t.note(format!("plan: {:?}", sc.fault.events));
    t.note(format!(
        "knobs: detect {:.0}us, backoff {:.0}us x{:.1} over {} retries, rebuild {:.0}us, \
         checkpoint {}",
        sc.fault.detect_timeout_us,
        sc.fault.backoff_base_us,
        sc.fault.backoff_factor,
        sc.fault.max_retries,
        sc.fault.rebuild_us,
        if sc.fault.checkpoint_period_us > 0.0 {
            format!("every {:.0}us", sc.fault.checkpoint_period_us)
        } else {
            "off".into()
        },
    ));
    Ok(t)
}

/// §Robustness sweep: seeded failure-rate × world grid on the cluster's
/// default Horovod variant — the table behind `mpi-dnn-train scenario
/// faults`.  Each grid point draws its own deterministic
/// [`FaultPlan::seeded_crash`] with the crash window set to the point's
/// fault-free iteration time, so the injected instant always lands
/// mid-iteration; same `(world, rate, seed)` ⇒ same table, bit-for-bit.
pub fn fault_sweep(
    cluster: crate::cluster::ClusterSpec,
    model: ModelProfile,
    max_world: usize,
    seed: u64,
    knobs: &crate::sim::FaultPlan,
) -> Result<Table> {
    use crate::sim::FaultPlan;
    use crate::strategies::Scenario;
    let mut worlds = vec![4usize];
    while *worlds.last().unwrap() * 2 <= max_world.max(4) {
        let next = worlds.last().unwrap() * 2;
        worlds.push(next);
    }
    let rates = [0.0f64, 0.25, 0.5, 1.0];
    let grid: Vec<(usize, f64)> =
        worlds.iter().flat_map(|&w| rates.iter().map(move |&r| (w, r))).collect();
    let cluster_name = cluster.name;
    let mut t = Table::new(
        &format!(
            "Fault sweep: seeded rank crashes, {} on {cluster_name} (failure rate × world)",
            model.name
        ),
        &["world", "rate", "crash", "img/s", "goodput", "recover", "lost work"],
    );
    let rows = par_map_ordered(grid, |(world, rate)| {
        let h = default_horovod(&cluster);
        let ws = WorldSpec::new(cluster.clone(), model.clone(), world);
        let base = match h.iteration(&ws) {
            Ok(b) => b,
            Err(_) => {
                let mut row = vec![world.to_string(), format!("{rate:.2}")];
                row.extend(["-", "n/a", "n/a", "-", "-"].map(String::from));
                return row;
            }
        };
        // the drawn events ride the sweep's shared recovery knobs
        let drawn = FaultPlan::seeded_crash(world, rate, base.iter.as_us(), seed);
        let plan = FaultPlan { events: drawn.events, ..knobs.clone() };
        if plan.is_empty() {
            return vec![
                world.to_string(),
                format!("{rate:.2}"),
                "none".into(),
                format!("{:.0}", base.imgs_per_sec),
                format!("{:.0}", base.imgs_per_sec),
                "-".into(),
                "-".into(),
            ];
        }
        let crash = plan.first_crash().expect("seeded plans only draw crashes");
        match h.iteration_in(&ws, &Scenario::with_fault(plan)) {
            Ok(r) => {
                let f = r.fault.expect("non-empty fault plan attaches a FaultReport");
                vec![
                    world.to_string(),
                    format!("{rate:.2}"),
                    format!("r{}@{}", crash.1, crash.0),
                    format!("{:.0}", base.imgs_per_sec),
                    format!("{:.0}", f.goodput_imgs_per_sec),
                    format!("{}", f.recover),
                    format!("{}", f.lost_work),
                ]
            }
            Err(_) => {
                let mut row =
                    vec![world.to_string(), format!("{rate:.2}"), format!("r{}", crash.1)];
                row.extend(["n/a", "n/a", "-", "-"].map(String::from));
                row
            }
        }
    });
    for row in rows {
        t.row(row);
    }
    t.note(format!(
        "seed {seed}: each point draws one crash with probability = rate, uniformly in the \
         middle 80% of its fault-free iteration; recovery = detect -> backoff -> elastic \
         rebuild over world-1 (deterministic — same seed, same table)"
    ));
    Ok(t)
}

/// Placement sweep: one (cluster, model, world) point across node
/// densities and NIC rail counts — the paper's 1-GPU-per-node layout vs
/// dense nodes whose co-located ranks share a NIC/PCIe bundle vs dense
/// nodes with multi-rail NICs.  Dense layouts run on the placed
/// `CommGraph` path (the serialized replay cannot express placement):
/// intra-node hops ride PCIe/NVLink instead of the wire, and co-located
/// ranks queue on their node's shared ports.
pub fn placement_sweep(
    cluster: crate::cluster::ClusterSpec,
    model: ModelProfile,
    world: usize,
    gpus_per_node: usize,
    rails: usize,
) -> Result<Table> {
    crate::ensure!(gpus_per_node >= 1, "gpus-per-node must be >= 1, got {gpus_per_node}");
    crate::ensure!(rails >= 1, "rails must be >= 1, got {rails}");
    // each rank occupies one rail, so rails beyond the ranks per node
    // would sit idle — an inert comparison is a request mistake
    crate::ensure!(
        rails <= gpus_per_node,
        "rails = {rails} exceeds gpus-per-node = {gpus_per_node}: the extra rails would be idle"
    );
    let mut layouts: Vec<(usize, usize)> = vec![(1, 1)];
    if gpus_per_node > 1 {
        layouts.push((gpus_per_node, 1));
    }
    if rails > 1 && !layouts.contains(&(gpus_per_node, rails)) {
        layouts.push((gpus_per_node, rails));
    }
    let cluster_name = cluster.name;
    let title = format!(
        "Placement sweep: {} on {cluster_name}@{world} (dense nodes / NIC rails)",
        model.name
    );
    let mut t = Table::new(
        &title,
        &["gpus/node", "rails", "Horovod img/s", "Horovod eff", "Baidu img/s", "gRPC img/s"],
    );
    let rows = par_map_ordered(layouts, |(g, r)| {
        let mut c = cluster.clone();
        c.gpus_per_node = g;
        c.nic_rails = r;
        let ws = WorldSpec::new(c.clone(), model.clone(), world);
        let fmt = |res: Result<crate::strategies::IterationReport>| match res {
            Ok(rep) => format!("{:.0}", rep.imgs_per_sec),
            Err(_) => "n/a".into(),
        };
        let h = default_horovod(&c).iteration(&ws);
        let eff = h
            .as_ref()
            .map(|rep| format!("{:.0}%", 100.0 * rep.scaling_efficiency))
            .unwrap_or_else(|_| "-".into());
        vec![
            g.to_string(),
            r.to_string(),
            fmt(h),
            eff,
            fmt(default_baidu(&c).iteration(&ws)),
            fmt(PsStrategy::grpc().iteration(&ws)),
        ]
    });
    for row in rows {
        t.row(row);
    }
    t.note(format!(
        "co-located ranks share their node's NIC port(s) and PCIe link; intra-node hops ride \
         PCIe at {:.2}x the wire time; rails split the node NIC round-robin",
        cluster.fabric.local_hop_factor()
    ));
    Ok(t)
}

/// §Overlap sweep: the comm stream-count knob (the
/// `HOROVOD_NUM_NCCL_STREAMS` analogue) on one (cluster, model, world)
/// point — how much Allreduce time hides under the backward pass once
/// fusion buffers (Horovod) / per-tensor rings (Baidu) may interleave
/// instead of serializing on the comm thread.  `streams = 1` is the
/// paper's serialized baseline; beyond it, per-rank wire/PCIe FIFO
/// contention arbitrates the in-flight graphs.  Powers of two up to
/// `max_streams` (at least [1, 2, 4]).
pub fn overlap_sweep(
    cluster: crate::cluster::ClusterSpec,
    model: ModelProfile,
    world: usize,
    max_streams: usize,
) -> Result<Table> {
    use crate::strategies::Scenario;
    let mut streams = vec![1usize];
    while *streams.last().unwrap() * 2 <= max_streams.max(4) {
        let next = streams.last().unwrap() * 2;
        streams.push(next);
    }
    let cluster_name = cluster.name;
    let mut t = Table::new(
        &format!("Overlap sweep: {} on {cluster_name}@{world} (comm streams)", model.name),
        &["streams", "Horovod img/s", "Horovod exposed", "Horovod eff", "Baidu img/s"],
    );
    let rows = par_map_ordered(streams, |s| {
        let sc = Scenario::overlap(s);
        let ws = WorldSpec::new(cluster.clone(), model.clone(), world);
        let h = default_horovod(&cluster).iteration_in(&ws, &sc);
        let (img, exposed, eff) = match &h {
            Ok(r) => (
                format!("{:.0}", r.imgs_per_sec),
                format!("{}", r.exposed_comm),
                format!("{:.0}%", 100.0 * r.scaling_efficiency),
            ),
            Err(_) => ("n/a".into(), "-".into(), "-".into()),
        };
        vec![
            s.to_string(),
            img,
            exposed,
            eff,
            match default_baidu(&cluster).iteration_in(&ws, &sc) {
                Ok(r) => format!("{:.0}", r.imgs_per_sec),
                Err(_) => "n/a".into(),
            },
        ]
    });
    for row in rows {
        t.row(row);
    }
    t.note(
        "streams > 1 launch ready collectives immediately, round-robin across lanes; \
         per-rank wire/PCIe FIFO contention arbitrates the interleaved graphs \
         (comm-thread serialization at streams = 1)",
    );
    Ok(t)
}

/// Ablation: fusion-cycle knob (`HOROVOD_CYCLE_TIME`) × scenario grid —
/// how the cycle choice interacts with degraded conditions.  The
/// straggler/jitter columns run on the per-rank `CommGraph` path, so the
/// knob's interplay with step-level skew propagation is what's measured.
pub fn ablation_cycle_grid(cluster_name: &str, world: usize) -> Result<Table> {
    use crate::strategies::Scenario;
    let cluster = presets::by_name(cluster_name)?;
    let model = resnet::resnet50();
    let scenarios: Vec<(&str, Scenario)> = vec![
        ("pristine", Scenario::default()),
        ("straggler 1×1.5", Scenario::straggler(1, 1.5)),
        ("jitter 250us", Scenario { jitter_us: 250.0, ..Scenario::default() }),
        ("link 50%", Scenario::link_loaded(0.5)),
    ];
    let mut headers = vec!["cycle".to_string()];
    headers.extend(scenarios.iter().map(|(n, _)| format!("img/s ({n})")));
    let mut t = Table::new(
        &format!("Ablation: fusion cycle × scenario (ResNet-50, {cluster_name}@{world})"),
        &headers.iter().map(|h| h.as_str()).collect::<Vec<_>>(),
    );
    let cycles = [500.0f64, 1_000.0, 2_500.0, 5_000.0, 10_000.0];
    let rows = par_map_ordered(cycles.iter().copied(), |cycle_us| {
        let mut h = default_horovod(&cluster);
        h.cycle_us = cycle_us;
        let ws = WorldSpec::new(cluster.clone(), model.clone(), world);
        let mut row = vec![format!("{:.1}ms", cycle_us / 1_000.0)];
        for (_, sc) in &scenarios {
            row.push(match h.iteration_in(&ws, sc) {
                Ok(r) => format!("{:.0}", r.imgs_per_sec),
                Err(_) => "n/a".into(),
            });
        }
        row
    });
    for row in rows {
        t.row(row);
    }
    t.note(
        "long cycles fuse more tensors per collective but delay the pipeline; \
         per-rank skew scenarios shift the optimum (fewer, larger buffers ride \
         out step-level jitter better)",
    );
    Ok(t)
}

/// §Robustness campaign comparison: every strategy runs the *same*
/// sustained-failure campaign (the seeded crash stream depends only on
/// `(world, mtbf, seed)`, never on the strategy), so the goodput column
/// is a like-for-like ranking of how each family's recovery model holds
/// up under churn.  The table behind `mpi-dnn-train scenario campaign`.
pub fn campaign_compare(
    cluster: crate::cluster::ClusterSpec,
    model: ModelProfile,
    world: usize,
    sc: &crate::strategies::Scenario,
) -> Result<Table> {
    use crate::sim::run_campaign;
    let cluster_name = cluster.name;
    let spec = sc.campaign.clone();
    let title = format!(
        "Campaign: {} iters, MTBF {:.0}us/rank, ckpt {} ({}, {cluster_name}@{world})",
        spec.iters,
        spec.mtbf_us,
        spec.policy.name(),
        model.name
    );
    let ws = WorldSpec::new(cluster, model, world);
    sc.validate()?;
    let strategies = crate::strategies::all_strategies();
    let mut t = Table::new(
        &title,
        &[
            "strategy",
            "goodput",
            "iters/s",
            "crashes",
            "rejoins",
            "ckpts",
            "rollback",
            "recovery",
            "rebuild",
            "makespan",
        ],
    );
    let rows = par_map_ordered(strategies.iter(), |s| {
        // unavailable / failing strategies keep their row with "n/a"
        // cells, same convention as the figure sweeps
        match run_campaign(s.as_ref(), &ws, sc) {
            Ok(r) => vec![
                s.name(),
                format!("{:.0}", r.goodput_imgs_per_sec),
                format!("{:.2}", r.effective_iters_per_sec),
                r.crashes.to_string(),
                r.rejoins.to_string(),
                r.checkpoints.to_string(),
                format!("{}", r.rollback_lost),
                format!("{}", r.recovery),
                format!("{}", r.rejoin_rebuild),
                format!("{}", r.makespan),
            ],
            Err(_) => {
                let mut row = vec![s.name(), "n/a".into(), "n/a".into()];
                row.extend(["-", "-", "-", "-", "-", "-", "-"].map(String::from));
                row
            }
        }
    });
    for row in rows {
        t.row(row);
    }
    t.note(format!(
        "seed {}: identical crash schedule for every strategy (policy-independent Poisson \
         arrivals at the system rate world/MTBF); repair {:.0}us mean, checkpoint cost {:.0}us",
        spec.seed, spec.repair_us, spec.ckpt_cost_us
    ));
    Ok(t)
}

/// One grid point of [`campaign_sweep`], structured so the tier-1
/// Young–Daly acceptance test asserts on numbers instead of table cells.
#[derive(Debug, Clone)]
pub struct CampaignPoint {
    pub strategy: String,
    /// System MTBF in units of the strategy's fault-free iteration.
    pub mtbf_iters: f64,
    pub policy: String,
    pub interval_us: f64,
    pub crashes: usize,
    pub checkpoints: usize,
    pub goodput: f64,
}

/// §Robustness campaign sweep grid: one strategy per family × system
/// MTBF × checkpoint policy, every knob sized off the strategy's own
/// measured iteration time so the policy comparison is meaningful on
/// any model/cluster.  The `fixed-tau` row hands the Young–Daly period
/// to the fixed policy verbatim — it must *match* `young-daly` exactly
/// (same resolved interval, same campaign), while `fixed-tight`
/// checkpoints every iteration and pays for it.
pub fn campaign_sweep_points(
    cluster: crate::cluster::ClusterSpec,
    model: ModelProfile,
    world: usize,
    seed: u64,
) -> Result<Vec<CampaignPoint>> {
    use crate::sim::{run_campaign, CampaignSpec, CheckpointPolicy};
    use crate::strategies::Scenario;
    crate::ensure!(
        world >= 3,
        "campaign sweep needs world >= 3 (crash recovery rebuilds over survivors), got {world}"
    );
    let strategies: Vec<Box<dyn Strategy>> = vec![
        Box::new(default_horovod(&cluster)),
        Box::new(default_baidu(&cluster)),
        Box::new(PsStrategy::grpc_mpi()),
    ];
    let iters = 160usize;
    // system MTBF as a multiple of the iteration time: churny and calm
    let mtbf_grid = [40.0f64, 100.0];
    let mut points = Vec::new();
    for s in &strategies {
        let ws = WorldSpec::new(cluster.clone(), model.clone(), world);
        let base = match s.iteration(&ws) {
            Ok(b) => b,
            Err(_) => continue, // family unavailable on this fabric
        };
        let iter_us = base.iter.as_us();
        for &m in &mtbf_grid {
            let mtbf_us = m * iter_us * world as f64; // per-rank MTBF
            let cost_us = 2.0 * iter_us;
            // the exact expression run_campaign resolves YoungDaly with,
            // so the fixed-tau row reproduces its interval bit-for-bit
            let tau_us = (2.0 * cost_us * (mtbf_us / world as f64)).sqrt();
            let policies: [(&str, CheckpointPolicy, f64); 4] = [
                ("off", CheckpointPolicy::Off, 0.0),
                ("fixed-tight", CheckpointPolicy::Fixed { period_us: iter_us }, cost_us),
                ("fixed-tau", CheckpointPolicy::Fixed { period_us: tau_us }, cost_us),
                ("young-daly", CheckpointPolicy::YoungDaly, cost_us),
            ];
            for (label, policy, ckpt_cost_us) in policies {
                let sc = Scenario {
                    campaign: CampaignSpec {
                        iters,
                        mtbf_us,
                        seed,
                        policy,
                        ckpt_cost_us,
                        repair_us: 10.0 * iter_us,
                    },
                    ..Scenario::default()
                };
                let r = run_campaign(s.as_ref(), &ws, &sc)?;
                points.push(CampaignPoint {
                    strategy: s.name(),
                    mtbf_iters: m,
                    policy: label.to_string(),
                    interval_us: r.checkpoint_interval_us,
                    crashes: r.crashes,
                    checkpoints: r.checkpoints,
                    goodput: r.goodput_imgs_per_sec,
                });
            }
        }
    }
    Ok(points)
}

/// §Robustness campaign sweep: the checkpoint-period × fault-rate ×
/// strategy grid behind `mpi-dnn-train scenario campaigns`.
pub fn campaign_sweep(
    cluster: crate::cluster::ClusterSpec,
    model: ModelProfile,
    world: usize,
    seed: u64,
) -> Result<Table> {
    let cluster_name = cluster.name;
    let model_name = model.name.clone();
    let points = campaign_sweep_points(cluster, model, world, seed)?;
    let mut t = Table::new(
        &format!(
            "Campaign sweep: checkpoint policy × failure rate, {model_name} on \
             {cluster_name}@{world} (160 iters per point)"
        ),
        &["strategy", "MTBF (iters)", "policy", "interval", "crashes", "ckpts", "goodput"],
    );
    for p in &points {
        t.row([
            p.strategy.clone(),
            format!("{:.0}", p.mtbf_iters),
            p.policy.clone(),
            if p.interval_us > 0.0 { format!("{:.0}us", p.interval_us) } else { "-".into() },
            p.crashes.to_string(),
            p.checkpoints.to_string(),
            format!("{:.0}", p.goodput),
        ]);
    }
    t.note(format!(
        "seed {seed}: per-point knobs sized off each strategy's measured iteration (system \
         MTBF in iterations, checkpoint cost 2 iterations, repair 10); fixed-tau hands the \
         Young-Daly period to the fixed policy and must tie it, fixed-tight checkpoints \
         every iteration"
    ));
    Ok(t)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn young_daly_beats_or_matches_fixed_across_the_campaign_sweep() {
        // the ISSUE acceptance bar: on every (strategy, MTBF) group of the
        // sweep grid, the young-daly row's goodput must be >= every fixed
        // row's.  fixed-tau resolves to the identical interval (exact tie);
        // fixed-tight pays a checkpoint every iteration and loses.
        let pts =
            campaign_sweep_points(presets::ri2(), mobilenet::mobilenet_v1(), 4, 11).unwrap();
        assert!(!pts.is_empty(), "sweep must cover at least one family");
        let mut groups: std::collections::BTreeMap<(String, u64), Vec<&CampaignPoint>> =
            std::collections::BTreeMap::new();
        for p in &pts {
            groups.entry((p.strategy.clone(), p.mtbf_iters as u64)).or_default().push(p);
        }
        for ((strategy, m), rows) in &groups {
            assert_eq!(rows.len(), 4, "{strategy}@{m}: off/fixed-tight/fixed-tau/young-daly");
            let yd = rows.iter().find(|p| p.policy == "young-daly").unwrap();
            assert!(yd.interval_us > 0.0);
            for p in rows.iter().filter(|p| p.policy.starts_with("fixed")) {
                assert!(
                    yd.goodput * (1.0 + 1e-9) >= p.goodput,
                    "{strategy}@{m}: young-daly {} must beat/match {} {}",
                    yd.goodput,
                    p.policy,
                    p.goodput
                );
            }
            // fixed-tau is handed the young-daly period verbatim: exact tie
            let tau = rows.iter().find(|p| p.policy == "fixed-tau").unwrap();
            assert_eq!(tau.interval_us, yd.interval_us, "{strategy}@{m}: tau interval");
            assert_eq!(tau.goodput, yd.goodput, "{strategy}@{m}: tau campaign is bit-identical");
            assert_eq!(tau.crashes, yd.crashes);
            assert_eq!(tau.checkpoints, yd.checkpoints);
        }
        // same seed + grid ⇒ bit-identical points
        let again =
            campaign_sweep_points(presets::ri2(), mobilenet::mobilenet_v1(), 4, 11).unwrap();
        assert_eq!(pts.len(), again.len());
        for (a, b) in pts.iter().zip(&again) {
            assert_eq!(a.goodput, b.goodput);
            assert_eq!(a.crashes, b.crashes);
        }
    }

    #[test]
    fn campaign_compare_covers_every_strategy() {
        use crate::sim::{CampaignSpec, CheckpointPolicy};
        use crate::strategies::Scenario;
        let sc = Scenario {
            campaign: CampaignSpec {
                iters: 8,
                mtbf_us: 0.0,
                seed: 3,
                policy: CheckpointPolicy::Off,
                ckpt_cost_us: 0.0,
                repair_us: 0.0,
            },
            ..Scenario::default()
        };
        let t =
            campaign_compare(presets::ri2(), mobilenet::mobilenet_v1(), 4, &sc).unwrap();
        assert_eq!(t.rows.len(), crate::strategies::all_strategies().len());
        assert_eq!(t.headers.len(), 10);
        // fault-free campaign: at least the MPI families produce real rows
        assert!(
            t.rows.iter().filter(|r| r[1] != "n/a").count() >= 4,
            "most strategies should run the campaign: {:?}",
            t.rows
        );
    }

    #[test]
    fn overlap_sweep_rows_and_monotone_throughput() {
        // streams 1/2/4 on a comm-bound point: Horovod img/s must be
        // nondecreasing in the stream count (and strictly better by 2)
        let t = overlap_sweep(presets::piz_daint(), mobilenet::mobilenet_v1(), 32, 4).unwrap();
        assert_eq!(t.headers.len(), 5);
        assert_eq!(t.rows.len(), 3);
        assert_eq!(
            t.rows.iter().map(|r| r[0].as_str()).collect::<Vec<_>>(),
            vec!["1", "2", "4"]
        );
        let imgs: Vec<f64> = t.rows.iter().map(|r| r[1].parse().unwrap()).collect();
        // rounded to whole img/s in the table, so >= here; the strict
        // full-precision pins live in des_regression / strategy tests
        assert!(imgs[1] >= imgs[0], "2 streams must not lose to serialized: {imgs:?}");
        assert!(imgs[2] >= imgs[1] * 0.999, "4 streams must not lose to 2: {imgs:?}");
        // the ceiling clamps to at least [1, 2, 4]
        let t = overlap_sweep(presets::ri2(), mobilenet::mobilenet_v1(), 4, 1).unwrap();
        assert_eq!(t.rows.len(), 3);
    }

    #[test]
    fn fig2_shape() {
        let t = fig2();
        assert_eq!(t.headers.len(), 4);
        assert_eq!(t.rows.len(), 8);
        // batch-64 row ordering K80 < P100 < V100
        let row64 = &t.rows[6];
        assert_eq!(row64[0], "64");
        let v: Vec<f64> = row64[1..].iter().map(|c| c.parse().unwrap()).collect();
        assert!(v[0] < v[1] && v[1] < v[2]);
        // diminishing returns past the sweet spot (paper's key insight)
        let k80_64: f64 = t.rows[6][1].parse().unwrap();
        let k80_128: f64 = t.rows[7][1].parse().unwrap();
        assert!(k80_128 / k80_64 < 1.15, "K80 gain past 64 should be small");
    }

    #[test]
    fn fig6_headline_ratios() {
        let t = fig6().unwrap();
        assert_eq!(t.rows.len(), 27); // 4B..256MB
        // H1: the small/medium NCCL2/Opt ratio must reach ≥5x
        let note = &t.notes[0];
        let measured: f64 = note
            .split("measured max ")
            .nth(1)
            .unwrap()
            .trim_end_matches('x')
            .parse()
            .unwrap();
        assert!(measured >= 5.0, "H1: got {measured}x");
    }

    #[test]
    fn two_jobs_families_and_cycle_grid_build() {
        use crate::models::mobilenet;
        for family in ["horovod", "ps", "grpc+verbs", "rdma", "horovod-mpi", "baidu", "baidu-mpi"]
        {
            let t = scenario_two_jobs(
                presets::ri2(),
                mobilenet::mobilenet_v1(),
                4,
                0.0,
                family,
            )
            .unwrap();
            assert_eq!(t.rows.len(), 3, "{family}: solo/A/B rows");
        }
        assert!(scenario_two_jobs(presets::ri2(), mobilenet::mobilenet_v1(), 4, 0.0, "gloo")
            .is_err());
        let g = ablation_cycle_grid("ri2", 4).unwrap();
        assert_eq!(g.rows.len(), 5);
        assert_eq!(g.headers.len(), 5); // cycle + 4 scenario columns
    }

    #[test]
    fn placement_sweep_builds_expected_layouts() {
        use crate::models::mobilenet;
        let t = placement_sweep(presets::ri2(), mobilenet::mobilenet_v1(), 8, 2, 2).unwrap();
        assert_eq!(t.rows.len(), 3, "(1,1), (2,1), (2,2) layouts");
        let layout = |i: usize| (t.rows[i][0].as_str(), t.rows[i][1].as_str());
        assert_eq!(layout(0), ("1", "1"));
        assert_eq!(layout(1), ("2", "1"));
        assert_eq!(layout(2), ("2", "2"));
        // every cell filled (all three families run at this point)
        for row in &t.rows {
            assert!(row.iter().all(|c| c != "n/a"), "row {row:?}");
        }
        // degenerate request: only the trivial layout
        let t1 = placement_sweep(presets::ri2(), mobilenet::mobilenet_v1(), 4, 1, 1).unwrap();
        assert_eq!(t1.rows.len(), 1);
        assert!(placement_sweep(presets::ri2(), mobilenet::mobilenet_v1(), 4, 0, 1).is_err());
        // idle rails (rails > gpus/node) are a request mistake
        assert!(placement_sweep(presets::ri2(), mobilenet::mobilenet_v1(), 4, 2, 4).is_err());
        assert!(placement_sweep(presets::ri2(), mobilenet::mobilenet_v1(), 4, 1, 2).is_err());
    }

    #[test]
    fn fault_compare_reports_recovery_for_every_family() {
        use crate::sim::FaultPlan;
        use crate::strategies::Scenario;
        let sc = Scenario::with_fault(FaultPlan::crash(1, 800.0));
        let t = fault_compare(presets::ri2(), mobilenet::mobilenet_v1(), 4, &sc).unwrap();
        assert_eq!(t.rows.len(), crate::strategies::all_strategies().len());
        for row in &t.rows {
            if row[1] == "n/a" {
                continue; // family unavailable on this fabric
            }
            assert_eq!(row[7], "3", "{}: a 4-rank crash leaves 3 survivors", row[0]);
            let base: f64 = row[1].parse().unwrap();
            let goodput: f64 = row[2].parse().unwrap();
            assert!(
                goodput < base,
                "{}: recovery + lost work must cost throughput ({goodput} vs {base})",
                row[0]
            );
        }
    }

    #[test]
    fn fault_sweep_is_deterministic_and_rate_gated() {
        use crate::sim::FaultPlan;
        let knobs = FaultPlan::default();
        let t = fault_sweep(presets::ri2(), mobilenet::mobilenet_v1(), 8, 42, &knobs).unwrap();
        assert_eq!(t.rows.len(), 8, "worlds [4, 8] x 4 rates");
        for row in &t.rows {
            match row[1].as_str() {
                "0.00" => assert_eq!(row[2], "none", "rate 0 never injects"),
                "1.00" => assert_ne!(row[2], "none", "rate 1 always injects"),
                _ => {}
            }
        }
        let again =
            fault_sweep(presets::ri2(), mobilenet::mobilenet_v1(), 8, 42, &knobs).unwrap();
        assert_eq!(t.rows, again.rows, "same seed must reproduce the sweep bit-for-bit");
    }

    #[test]
    fn fig9_all_models_build() {
        for m in ["nasnet", "resnet50", "mobilenet"] {
            let t = fig9(m).unwrap();
            assert_eq!(t.rows.len(), 8);
        }
        assert!(fig9("vgg").is_err());
    }
}
