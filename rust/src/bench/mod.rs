//! Figure-regeneration harness: one function per table/figure in the
//! paper's evaluation, each returning a `Table` the CLI prints (and can
//! emit as JSON).  `rust/benches/fig*.rs` are thin wrappers over these.

pub mod figures;
pub mod perf;
pub mod table;

pub use figures::*;
pub use table::Table;
