//! Regression pins for the `CommOp`→`Engine` port AND the `CommGraph`
//! port on top of it:
//!
//!  1. the DES-scheduled Horovod/Baidu iteration times must stay within
//!     tolerance of the pre-refactor closed-form accumulators on the
//!     paper configurations, so the Figure 3/7/8/9 assertions (efficiency
//!     ordering, MPI-Opt > stock, ≈90% Owens@64) keep meaning what they
//!     meant;
//!  2. the **zero-skew equivalence suite**: with no scenario
//!     perturbation, per-rank `CommGraph` execution must reproduce the
//!     serialized critical-path timings (Horovod/Baidu via
//!     `iteration_graph`, PS via the retained PR-1
//!     `iteration_reference`);
//!  3. straggler propagation: a slow rank delays only its *dependent*
//!     ring steps, deterministically.
//!
//! The analytic reference below *is* the old model, re-expressed through
//! the public cost APIs: a float `thread_free` timeline serializing fused
//! buffers (Horovod) or per-tensor rings (Baidu).  The only deviation the
//! DES may introduce is nanosecond rounding per scheduled op.

use mpi_dnn_train::cluster::presets;
use mpi_dnn_train::comm::allreduce::Algo;
use mpi_dnn_train::comm::nccl::NcclWorld;
use mpi_dnn_train::comm::{MpiFlavor, MpiWorld};
use mpi_dnn_train::models::{mobilenet, nasnet, resnet, ModelProfile};
use mpi_dnn_train::strategies::{Baidu, Horovod, HorovodBackend, PsStrategy, Scenario, Strategy, WorldSpec};

/// Relative tolerance: per-op ns rounding across a few hundred ops is
/// well under a microsecond; iterations are 1e4–1e6 µs.
const REL_TOL: f64 = 2e-3;

fn assert_close(des_us: f64, analytic_us: f64, what: &str) {
    let rel = (des_us - analytic_us).abs() / analytic_us.max(1e-9);
    assert!(
        rel < REL_TOL,
        "{what}: DES {des_us:.3}us vs analytic {analytic_us:.3}us (rel {rel:.2e})"
    );
}

/// Pre-refactor Horovod model: background-thread float timeline.
fn analytic_horovod_us(h: &Horovod, ws: &WorldSpec) -> f64 {
    if ws.world == 1 {
        return ws.compute_time().as_us();
    }
    let coord = h.coord_us(ws);
    let pcie = ws.cluster.fabric.pcie.beta_gbs * 1e3;
    let mut thread_free = 0.0f64;
    let mut staging_total = 0.0f64;
    for (ready, bytes) in h.fusion_schedule(ws) {
        let r = match h.backend {
            HorovodBackend::Mpi(flavor) => {
                MpiWorld::new(flavor, ws.cluster.clone()).allreduce_latency(ws.world, bytes)
            }
            HorovodBackend::Nccl => {
                NcclWorld::new(ws.cluster.clone()).unwrap().allreduce_latency(ws.world, bytes)
            }
        };
        let staging = (4.0 * bytes as f64 / pcie).min(r.cost.staging_us);
        let start = thread_free.max(ready.as_us());
        thread_free = start + coord + r.time.as_us();
        staging_total += staging;
    }
    let p = ws.world as f64;
    let dilated = ws.compute_time().as_us() * (1.0 + h.runtime_tax * (1.0 - 1.0 / p));
    let skew = h.skew_us_per_rank * p;
    thread_free.max(dilated + staging_total) + skew
}

/// Pre-refactor Baidu model: per-tensor pipelined rings on one timeline.
fn analytic_baidu_us(b: &Baidu, ws: &WorldSpec) -> f64 {
    const RING_PIPELINE: f64 = 8.0;
    let small_override = mpi_dnn_train::comm::mpi::SMALL_MSG_BYTES + 1;
    if ws.world == 1 {
        return ws.compute_time().as_us();
    }
    let w = MpiWorld::new(b.flavor, ws.cluster.clone());
    let pcie = ws.cluster.fabric.pcie.beta_gbs * 1e3;
    let mut thread_free = 0.0f64;
    let mut staging_total = 0.0f64;
    for (i, ready) in ws.tensor_readiness() {
        let bytes = ws.model.tensors[i].bytes();
        let (_, mut ctx) = w.plan(bytes.max(small_override));
        ctx.wire.beta_gbs /= ws.cluster.fabric.contention_factor(ws.world);
        let n = (bytes / 4).max(1);
        let full = mpi_dnn_train::comm::allreduce::shadow_cost(Algo::Ring, ws.world, n, &mut ctx);
        let fixed = mpi_dnn_train::comm::allreduce::shadow_cost(Algo::Ring, ws.world, 1, &mut ctx)
            .time
            .as_us();
        let total = (full.time.as_us() - fixed).max(0.0) + fixed / RING_PIPELINE;
        let staging = (4.0 * bytes as f64 / pcie).min(full.cost.staging_us);
        let start = thread_free.max(ready.as_us());
        thread_free = start + total;
        staging_total += staging;
    }
    let p = ws.world as f64;
    let dilated = ws.compute_time().as_us() * (1.0 + b.runtime_tax * (1.0 - 1.0 / p));
    let skew = b.skew_us_per_rank * p;
    thread_free.max(dilated + staging_total) + skew
}

#[test]
fn horovod_des_matches_analytic_on_paper_configs() {
    let points: Vec<(&str, WorldSpec, Horovod)> = vec![
        (
            "fig7 ri2@16 stock",
            WorldSpec::new(presets::ri2(), resnet::resnet50(), 16),
            Horovod::mpi(MpiFlavor::Mvapich2),
        ),
        (
            "fig7 ri2@16 opt",
            WorldSpec::new(presets::ri2(), resnet::resnet50(), 16),
            Horovod::mpi(MpiFlavor::Mvapich2GdrOpt),
        ),
        (
            "fig7 ri2@16 nccl",
            WorldSpec::new(presets::ri2(), resnet::resnet50(), 16),
            Horovod::nccl(),
        ),
        (
            "fig8 owens@64 opt",
            WorldSpec::new(presets::owens(), resnet::resnet50(), 64),
            Horovod::mpi(MpiFlavor::Mvapich2GdrOpt),
        ),
        (
            "fig9 pizdaint@128 resnet",
            WorldSpec::new(presets::piz_daint(), resnet::resnet50(), 128),
            Horovod::mpi(MpiFlavor::CrayMpich),
        ),
        (
            "fig9 pizdaint@128 mobilenet",
            WorldSpec::new(presets::piz_daint(), mobilenet::mobilenet_v1(), 128),
            Horovod::mpi(MpiFlavor::CrayMpich),
        ),
        (
            "fig9 pizdaint@64 nasnet",
            WorldSpec::new(presets::piz_daint(), nasnet::nasnet_large(), 64),
            Horovod::mpi(MpiFlavor::CrayMpich),
        ),
    ];
    for (what, ws, h) in points {
        let des = h.iteration(&ws).unwrap().iter.as_us();
        let analytic = analytic_horovod_us(&h, &ws);
        assert_close(des, analytic, what);
    }
}

#[test]
fn baidu_des_matches_analytic_on_paper_configs() {
    let points: Vec<(&str, ModelProfile, usize, Baidu)> = vec![
        ("fig3 ri2@16", resnet::resnet50(), 16, Baidu::new()),
        ("fig9 pizdaint@64 mobilenet", mobilenet::mobilenet_v1(), 64, Baidu::with_flavor(MpiFlavor::CrayMpich)),
        ("fig9 pizdaint@32 resnet", resnet::resnet50(), 32, Baidu::with_flavor(MpiFlavor::CrayMpich)),
    ];
    for (what, model, world, b) in points {
        let cluster = if what.contains("ri2") { presets::ri2() } else { presets::piz_daint() };
        let ws = WorldSpec::new(cluster, model, world);
        let des = b.iteration(&ws).unwrap().iter.as_us();
        let analytic = analytic_baidu_us(&b, &ws);
        assert_close(des, analytic, what);
    }
}

#[test]
fn graph_replay_matches_serialized_on_paper_configs() {
    // the zero-skew equivalence suite: forcing per-rank CommGraph
    // execution under a neutral scenario must reproduce the serialized
    // critical-path timings the figures (and the analytic pins above)
    // are built on — so the Figure 3/7/8/9 claims survive the port.
    let neutral = Scenario::default();
    let horovod_points: Vec<(&str, WorldSpec, Horovod)> = vec![
        (
            "fig7 ri2@16 opt",
            WorldSpec::new(presets::ri2(), resnet::resnet50(), 16),
            Horovod::mpi(MpiFlavor::Mvapich2GdrOpt),
        ),
        (
            "fig7 ri2@16 nccl",
            WorldSpec::new(presets::ri2(), resnet::resnet50(), 16),
            Horovod::nccl(),
        ),
        (
            "fig8 owens@64 opt",
            WorldSpec::new(presets::owens(), resnet::resnet50(), 64),
            Horovod::mpi(MpiFlavor::Mvapich2GdrOpt),
        ),
        (
            "fig9 pizdaint@128 resnet",
            WorldSpec::new(presets::piz_daint(), resnet::resnet50(), 128),
            Horovod::mpi(MpiFlavor::CrayMpich),
        ),
        (
            "fig9 pizdaint@128 mobilenet",
            WorldSpec::new(presets::piz_daint(), mobilenet::mobilenet_v1(), 128),
            Horovod::mpi(MpiFlavor::CrayMpich),
        ),
    ];
    for (what, ws, h) in horovod_points {
        let serial = h.iteration(&ws).unwrap().iter.as_us();
        let graph = h.iteration_graph(&ws, &neutral).unwrap().iter.as_us();
        assert_close(graph, serial, &format!("graph {what}"));
    }
    let baidu_points: Vec<(&str, WorldSpec, Baidu)> = vec![
        (
            "fig3 ri2@16",
            WorldSpec::new(presets::ri2(), resnet::resnet50(), 16),
            Baidu::new(),
        ),
        (
            "fig9 pizdaint@32 resnet",
            WorldSpec::new(presets::piz_daint(), resnet::resnet50(), 32),
            Baidu::with_flavor(MpiFlavor::CrayMpich),
        ),
    ];
    for (what, ws, b) in baidu_points {
        let serial = b.iteration(&ws).unwrap().iter.as_us();
        let graph = b.iteration_graph(&ws, &neutral).unwrap().iter.as_us();
        assert_close(graph, serial, &format!("graph {what}"));
    }
}

#[test]
fn ps_graph_port_matches_pr1_reference() {
    // PS has no closed-form reference (its timings are queueing), so the
    // pre-graph implementation is retained verbatim as the oracle: the
    // per-shard fan-in DAGs must reproduce it on the paper configs.
    let neutral = Scenario::default();
    for world in [4usize, 16] {
        let ws = WorldSpec::new(presets::ri2(), resnet::resnet50(), world);
        for s in [
            PsStrategy::grpc(),
            PsStrategy::grpc_mpi(),
            PsStrategy::grpc_verbs(),
            PsStrategy::rdma(),
        ] {
            let graph = s.iteration(&ws).unwrap().iter.as_us();
            let reference = s.iteration_reference(&ws, &neutral).unwrap().iter.as_us();
            assert_close(graph, reference, &format!("{} ri2@{world}", s.name()));
        }
    }
}

#[test]
fn infinite_rpc_window_is_bit_identical_to_the_unbounded_path() {
    // §Transports: a window wider than any shard count must route the
    // PS family through the stream-lane machinery yet reproduce the
    // unbounded graph path's schedule exactly — SimTime equality, not
    // tolerance, for every transport on the paper configs.
    let wide = Scenario::windowed(1 << 20);
    for world in [4usize, 16] {
        let ws = WorldSpec::new(presets::ri2(), resnet::resnet50(), world);
        for s in [
            PsStrategy::grpc(),
            PsStrategy::grpc_mpi(),
            PsStrategy::grpc_verbs(),
            PsStrategy::rdma(),
        ] {
            let base = s.iteration(&ws).unwrap().iter;
            let lane = s.iteration_in(&ws, &wide).unwrap().iter;
            assert_eq!(
                lane,
                base,
                "{} ri2@{world}: the infinite-window lane path diverged from the \
                 unbounded reference",
                s.name()
            );
        }
    }
}

#[test]
fn straggler_propagation_is_step_local_and_deterministic() {
    use mpi_dnn_train::comm::allreduce::shadow_steps;
    use mpi_dnn_train::comm::graph::{ring_graph, GraphOverlay, GraphResources, GraphTemplate};
    use mpi_dnn_train::sim::Engine;

    // a real RI2 ring (per-step costs from the validated models), built
    // ONCE as a template and replayed under overlays (§Perf path)
    let p = 8usize;
    let w = MpiWorld::new(MpiFlavor::Mvapich2GdrOpt, presets::ri2());
    let (_, mut ctx) = w.plan(1 << 20);
    let (_, steps) = shadow_steps(Algo::Ring, p, (1 << 20) / 4, &mut ctx);
    let t = GraphTemplate::new(ring_graph(p, &steps));

    let run = |ov: &GraphOverlay| {
        let mut e = Engine::new();
        let res = GraphResources::install(&mut e, p);
        let run = t.execute(&mut e, res.mapper(), ov, Box::new(|_| {}));
        e.run();
        let r = run.borrow();
        r.finish.clone()
    };
    let base = run(&GraphOverlay::neutral());
    let mut ov = GraphOverlay::neutral();
    ov.scale_rank(p, 3, 2.0); // rank 3 runs 2x slow
    let a = run(&ov);
    let b = run(&ov);
    assert_eq!(a, b, "perturbed template replays must be bit-identical");

    // ring builder layout: node index = step * p + rank; skew cone:
    // (r, s) is delayed iff s >= ring-distance(3 -> r)
    let id = |r: usize, s: usize| s * p + r;
    for (r, s) in [(4usize, 0usize), (5, 1), (6, 2), (2, 5)] {
        assert_eq!(a[id(r, s)], base[id(r, s)], "(r{r}, s{s}) is outside the cone");
    }
    for (r, s) in [(3usize, 0usize), (4, 1), (5, 2), (6, 3)] {
        assert!(
            a[id(r, s)] > base[id(r, s)],
            "(r{r}, s{s}) must inherit the straggler's delay"
        );
    }
}

#[test]
fn cached_template_iterations_are_replay_stable() {
    // §Perf: the first perturbed iteration builds graph templates, the
    // second replays them from cache — both must produce the exact same
    // iteration time (SimTime equality, not tolerance), for every
    // graph-path strategy family.
    let sc = Scenario {
        straggler_ranks: 1,
        straggler_factor: 1.5,
        jitter_us: 120.0,
        seed: 9,
        ..Scenario::default()
    };
    let ws = WorldSpec::new(presets::ri2(), resnet::resnet50(), 16);
    let horovod = Horovod::mpi(MpiFlavor::Mvapich2GdrOpt);
    let baidu = Baidu::new();
    let strategies: [&dyn Strategy; 2] = [&horovod, &baidu];
    for s in strategies {
        let a = s.iteration_in(&ws, &sc).unwrap();
        let b = s.iteration_in(&ws, &sc).unwrap();
        assert_eq!(a.iter, b.iter, "{}: warm-cache replay diverged", s.name());
        assert_eq!(
            a.engine_events, b.engine_events,
            "{}: warm-cache event count diverged",
            s.name()
        );
        assert!(a.engine_events > 0, "{}: graph path must report events", s.name());
    }
    let ps = PsStrategy::grpc();
    let a = ps.iteration_in(&ws, &sc).unwrap();
    let b = ps.iteration_in(&ws, &sc).unwrap();
    assert_eq!(a.iter, b.iter, "PS: shard-template replay diverged");
    assert_eq!(a.engine_events, b.engine_events);
}

#[test]
fn nic_sharing_monotonicity_at_graph_level() {
    // Uniform wire-only collectives isolate the NIC layout from the
    // intra-node hop re-costing: the private (1 GPU/node) layout has
    // zero contention, so it achieves the chain-length lower bound every
    // layout is bounded below by — sharing a NIC can never be faster —
    // and 2 ranks/node × 2 rails maps every rank onto its own port,
    // which is structurally the private layout again.
    use mpi_dnn_train::cluster::Placement;
    use mpi_dnn_train::comm::graph::{execute, rhd_graph, ring_graph, CommGraph, GraphResources};
    use mpi_dnn_train::comm::{CostBreakdown, StepCost};
    use mpi_dnn_train::sim::{Engine, SimTime};

    let wire_steps = |count: usize, us: f64| -> Vec<StepCost> {
        vec![
            StepCost {
                cost: CostBreakdown { wire_us: us, ..Default::default() },
                gpu_reduce: false
            };
            count
        ]
    };
    let run = |g: &CommGraph, p: usize, place: Placement| -> SimTime {
        let mut e = Engine::new();
        let res = GraphResources::install_placed(&mut e, p, place);
        execute(&mut e, g, res.mapper(), Box::new(|_| {}));
        e.run()
    };
    for p in [4usize, 8] {
        let graphs = [
            ("ring", ring_graph(p, &wire_steps(2 * (p - 1), 10.0)), 2 * (p - 1)),
            (
                "rhd",
                rhd_graph(p, &wire_steps(2 * p.trailing_zeros() as usize, 10.0)),
                2 * p.trailing_zeros() as usize,
            ),
        ];
        for (name, g, steps) in graphs {
            let private = run(&g, p, Placement::one_per_node());
            let shared = run(&g, p, Placement::new(2, 1));
            let railed = run(&g, p, Placement::new(2, 2));
            // zero-contention bound: private equals the serialized chain
            assert_eq!(
                private,
                SimTime::from_us(steps as f64 * 10.0),
                "{name} p={p}: private layout must be contention-free"
            );
            assert!(
                shared >= private,
                "{name} p={p}: sharing a NIC made the collective faster ({shared} < {private})"
            );
            assert_eq!(
                railed, private,
                "{name} p={p}: 2 ranks × 2 rails must equal the private layout"
            );
            assert!(railed <= shared, "{name} p={p}: a second rail slowed the collective");
        }
    }
}

#[test]
fn dense_placement_runs_are_replay_stable() {
    // The dense-node pins: 2- and 4-GPU-per-node Horovod/Baidu/PS runs
    // route onto the placed graph path, converge, and replay
    // bit-identically (the second call replays warm-cached templates —
    // warm-vs-cold equality under placement).
    use mpi_dnn_train::sim::SimTime;
    for gpn in [2usize, 4] {
        let mut cluster = presets::piz_daint();
        cluster.gpus_per_node = gpn;
        let ws = WorldSpec::new(cluster, mobilenet::mobilenet_v1(), 16);
        let horovod = Horovod::mpi(MpiFlavor::CrayMpich);
        let baidu = Baidu::with_flavor(MpiFlavor::CrayMpich);
        let ps = PsStrategy::grpc();
        let strategies: [&dyn Strategy; 3] = [&horovod, &baidu, &ps];
        for s in strategies {
            let a = s.iteration(&ws).unwrap();
            let b = s.iteration(&ws).unwrap();
            assert_eq!(a.iter, b.iter, "{} gpn={gpn}: dense replay diverged", s.name());
            assert_eq!(
                a.engine_events, b.engine_events,
                "{} gpn={gpn}: dense event count diverged",
                s.name()
            );
            assert!(
                a.engine_events > 0,
                "{} gpn={gpn}: dense run must ride the engine",
                s.name()
            );
            assert!(a.iter > SimTime::ZERO);
        }
    }
}

#[test]
fn dense_placement_monotonicity_pins() {
    // Strategy-level monotonicity on a comm-bound point: a second rail
    // never slows anyone, and with full rails a dense node is the
    // private-port layout PLUS the node-locality discount (co-located
    // worker-server transfers ride PCIe off the NIC), so it can only be
    // at least as fast as the paper's 1-GPU-per-node layout.
    let model = mobilenet::mobilenet_v1();
    let mk_ws = |gpn: usize, rails: usize| {
        let mut c = presets::ri2();
        c.gpus_per_node = gpn;
        c.nic_rails = rails;
        WorldSpec::new(c, model.clone(), 8)
    };
    let ps = PsStrategy::grpc();
    let trivial = ps.iteration(&mk_ws(1, 1)).unwrap().iter;
    let shared = ps.iteration(&mk_ws(2, 1)).unwrap().iter;
    let railed = ps.iteration(&mk_ws(2, 2)).unwrap().iter;
    assert!(railed <= shared, "a second PS rail cannot slow the fan-in: {railed} vs {shared}");
    // private ports again (2 servers/node × 2 rails) + each port carries
    // fewer remote transfers (co-located pairs moved onto PCIe, at
    // local_hop_factor <= 1 on RI2): can only be at least as fast
    assert!(
        railed <= trivial,
        "full rails + node locality cannot slow the fan-in: {railed} vs {trivial}"
    );

    // allreduce families: a second rail never slows a dense iteration
    let h = Horovod::mpi(MpiFlavor::Mvapich2GdrOpt);
    let h1 = h.iteration(&mk_ws(2, 1)).unwrap().iter;
    let h2 = h.iteration(&mk_ws(2, 2)).unwrap().iter;
    assert!(h2 <= h1, "second rail slowed Horovod: {h2} vs {h1}");
    let b = Baidu::new();
    let b1 = b.iteration(&mk_ws(2, 1)).unwrap().iter;
    let b2 = b.iteration(&mk_ws(2, 2)).unwrap().iter;
    assert!(b2 <= b1, "second rail slowed Baidu: {b2} vs {b1}");
}

#[test]
fn overlap_streams_reduce_iteration_and_are_monotone_in_depth() {
    // §Overlap pins on the paper's comm-bound worst case (MobileNet at
    // scale, Fig 9): (1) depth = 1 reproduces the serialized launch
    // order bit-for-bit even with two lanes configured; (2) two streams
    // strictly hide communication under backprop; (3) more streams and
    // deeper in-flight caps never hurt.
    let ws = WorldSpec::new(presets::piz_daint(), mobilenet::mobilenet_v1(), 64);
    let h = Horovod::mpi(MpiFlavor::CrayMpich);
    let base = h.iteration(&ws).unwrap().iter;

    // depth = 1 ≡ the serialized comm-thread order on the graph path
    let graph1 = h.iteration_graph(&ws, &Scenario::default()).unwrap().iter;
    let s2d1 = h
        .iteration_in(&ws, &Scenario { streams: 2, depth: 1, ..Scenario::default() })
        .unwrap()
        .iter;
    assert_eq!(
        s2d1, graph1,
        "two lanes at depth 1 must replay the serialized hand-off order exactly"
    );

    // overlap strictly reduces the comm-bound iteration
    let s2 = h.iteration_in(&ws, &Scenario::overlap(2)).unwrap().iter;
    assert!(s2 < base, "2 streams must hide comm under backprop: {s2} vs {base}");
    let s4 = h.iteration_in(&ws, &Scenario::overlap(4)).unwrap().iter;
    assert!(s4 <= s2, "4 streams must not lose to 2: {s4} vs {s2}");

    // monotone in the depth cap at a fixed stream count
    let at_depth = |d: usize| {
        h.iteration_in(&ws, &Scenario { streams: 4, depth: d, ..Scenario::default() })
            .unwrap()
            .iter
    };
    let (d1, d2, d4) = (at_depth(1), at_depth(2), at_depth(4));
    assert!(d2 <= d1, "depth 2 must not lose to depth 1: {d2} vs {d1}");
    assert!(d4 <= d2, "depth 4 must not lose to depth 2: {d4} vs {d2}");
    assert_eq!(d4, s4, "an uncapped depth equals depth = streams");

    // Baidu rides the same lanes (smaller world: per-tensor rings build
    // ~80 graphs per iteration, and tests run unoptimized)
    let bws = WorldSpec::new(presets::piz_daint(), mobilenet::mobilenet_v1(), 32);
    let b = Baidu::with_flavor(MpiFlavor::CrayMpich);
    let b_base = b.iteration(&bws).unwrap().iter;
    let b2 = b.iteration_in(&bws, &Scenario::overlap(2)).unwrap().iter;
    assert!(b2 < b_base, "Baidu: 2 streams must overlap rings: {b2} vs {b_base}");
}

#[test]
fn overlap_replays_are_stable_and_compose_with_skew() {
    // warm-cache overlapped replays are bit-identical, and overlap
    // composes with per-rank skew (straggler + jitter) without breaking
    // determinism
    let ws = WorldSpec::new(presets::ri2(), resnet::resnet50(), 16);
    let h = Horovod::mpi(MpiFlavor::Mvapich2GdrOpt);
    let sc = Scenario {
        streams: 2,
        straggler_ranks: 1,
        straggler_factor: 1.5,
        jitter_us: 100.0,
        seed: 11,
        ..Scenario::default()
    };
    let a = h.iteration_in(&ws, &sc).unwrap();
    let b = h.iteration_in(&ws, &sc).unwrap();
    assert_eq!(a.iter, b.iter, "overlapped replay diverged");
    assert_eq!(a.engine_events, b.engine_events);
    // the comm-thread ledger reports one lane launch per fusion buffer
    // (buffers re-packed under the straggler's compute stretch)
    let thread = a.resource_util.iter().find(|u| u.name == "comm-thread").unwrap();
    assert_eq!(
        thread.served as usize,
        h.fusion_schedule_in(&ws, sc.compute_stretch()).len()
    );
}

#[test]
fn parallel_sweeps_are_deterministic() {
    // The sweep drivers fan points across threads; each point owns its
    // engine, so two runs must produce byte-identical tables.
    let a = mpi_dnn_train::bench::fig3().unwrap();
    let b = mpi_dnn_train::bench::fig3().unwrap();
    assert_eq!(a.rows, b.rows);
    let a9 = mpi_dnn_train::bench::fig9("mobilenet").unwrap();
    let b9 = mpi_dnn_train::bench::fig9("mobilenet").unwrap();
    assert_eq!(a9.rows, b9.rows);
}

#[test]
fn des_preserves_figure_orderings() {
    // The headline orderings the paper tables assert, spot-checked at the
    // strategy level after the port (cheap subset of the figure tests).
    let ws = WorldSpec::new(presets::owens(), resnet::resnet50(), 64);
    let stock = Horovod::mpi(MpiFlavor::Mvapich2).iteration(&ws).unwrap();
    let opt = Horovod::mpi(MpiFlavor::Mvapich2GdrOpt).iteration(&ws).unwrap();
    assert!(opt.imgs_per_sec > stock.imgs_per_sec);
    assert!(opt.scaling_efficiency > 0.80 && opt.scaling_efficiency <= 1.0);
}
