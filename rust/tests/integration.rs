//! Integration tests: cross-module behaviour of the full stack —
//! runtime (PJRT) × comm (real allreduce) × trainer × bench harness ×
//! config launcher.  Everything here exercises at least two layers.

use mpi_dnn_train::bench;
use mpi_dnn_train::cluster::presets;
use mpi_dnn_train::comm::{MpiFlavor, MpiWorld};
use mpi_dnn_train::config::ExperimentConfig;
use mpi_dnn_train::models;
use mpi_dnn_train::runtime;
use mpi_dnn_train::strategies::{self, Strategy as _, WorldSpec};
use mpi_dnn_train::trainer::{TrainConfig, Trainer};

fn have(config: &str) -> bool {
    runtime::artifacts_dir()
        .map(|d| runtime::config_available(&d, config))
        .unwrap_or(false)
}

// ---------- trainer × runtime × comm ----------

#[test]
fn e2e_tiny_loss_decreases_under_every_flavor() {
    if !have("tiny") {
        eprintln!("skipping: tiny artifacts missing");
        return;
    }
    let client = mpi_dnn_train::runtime::client::shared().unwrap();
    for flavor in [MpiFlavor::Mvapich2, MpiFlavor::Mvapich2GdrOpt, MpiFlavor::CrayMpich] {
        let cfg = TrainConfig {
            model_config: "tiny".into(),
            world: 3, // non-power-of-two exercises the RHD pre/post phase
            steps: 25,
            flavor,
            log_every: 0,
            ..Default::default()
        };
        let r = Trainer::new(&client, cfg).unwrap().train().unwrap();
        assert!(
            r.final_loss() < r.initial_loss(),
            "{flavor:?}: loss {} -> {}",
            r.initial_loss(),
            r.final_loss()
        );
    }
}

#[test]
fn e2e_training_is_deterministic() {
    if !have("tiny") {
        return;
    }
    let client = mpi_dnn_train::runtime::client::shared().unwrap();
    let mk = || TrainConfig {
        model_config: "tiny".into(),
        world: 2,
        steps: 8,
        seed: 123,
        log_every: 0,
        ..Default::default()
    };
    let a = Trainer::new(&client, mk()).unwrap().train().unwrap();
    let b = Trainer::new(&client, mk()).unwrap().train().unwrap();
    assert_eq!(a.losses, b.losses, "same seed must reproduce the loss curve");
    assert_eq!(a.sim_time, b.sim_time, "virtual clock must be deterministic");
}

#[test]
fn e2e_flavors_agree_on_numerics() {
    // Different MPI flavors change TIMING, not MATH: same seed ⇒ same curve.
    if !have("tiny") {
        return;
    }
    let client = mpi_dnn_train::runtime::client::shared().unwrap();
    let mk = |flavor| TrainConfig {
        model_config: "tiny".into(),
        world: 4,
        steps: 6,
        flavor,
        log_every: 0,
        ..Default::default()
    };
    let a = Trainer::new(&client, mk(MpiFlavor::Mvapich2)).unwrap().train().unwrap();
    let b = Trainer::new(&client, mk(MpiFlavor::Mvapich2GdrOpt)).unwrap().train().unwrap();
    for (x, y) in a.losses.iter().zip(&b.losses) {
        assert!((x - y).abs() < 1e-4, "flavors diverged: {x} vs {y}");
    }
    assert_ne!(a.sim_time, b.sim_time, "timing should differ between flavors");
}

// ---------- figure harness smoke (all layers below the CLI) ----------

#[test]
fn all_figures_generate() {
    let _ = bench::fig2();
    let t3 = bench::fig3().unwrap();
    assert_eq!(t3.rows.len(), 5);
    let t4 = bench::fig4().unwrap();
    assert_eq!(t4.rows.len(), 27);
    let _ = bench::fig6().unwrap();
    let t7 = bench::fig7().unwrap();
    assert_eq!(t7.rows.len(), 5);
    let t8 = bench::fig8().unwrap();
    assert_eq!(t8.rows.len(), 7);
    let t9 = bench::fig9("mobilenet").unwrap();
    assert_eq!(t9.rows.len(), 8);
    let _ = bench::ablation_fusion("owens", 16).unwrap();
}

#[test]
fn paper_insight_1_no_grpc_beats_grpc_at_16() {
    // "No-gRPC designs achieve better performance compared to gRPC-based
    // approaches for most configurations" — checked on RI2@16 ResNet-50.
    let ws = WorldSpec::new(presets::ri2(), models::by_name("resnet50").unwrap(), 16);
    let grpc_best = ["grpc", "grpc+mpi", "grpc+verbs"]
        .iter()
        .map(|n| strategies::by_name(n).unwrap().iteration(&ws).unwrap().imgs_per_sec)
        .fold(0.0, f64::max);
    let nogrpc_worst = ["baidu", "horovod-mpi", "horovod-nccl", "horovod-mpi-opt"]
        .iter()
        .map(|n| strategies::by_name(n).unwrap().iteration(&ws).unwrap().imgs_per_sec)
        .fold(f64::INFINITY, f64::min);
    assert!(
        nogrpc_worst > grpc_best * 0.95,
        "No-gRPC worst ({nogrpc_worst:.0}) should be ≥ gRPC best ({grpc_best:.0})"
    );
}

#[test]
fn headline_h3_owens_efficiency() {
    // ≈90% scaling efficiency for ResNet-50 on 64 GPUs with MPI-Opt.
    let ws = WorldSpec::new(presets::owens(), models::by_name("resnet50").unwrap(), 64);
    let r = strategies::by_name("horovod-mpi-opt").unwrap().iteration(&ws).unwrap();
    assert!(
        (0.80..=1.0).contains(&r.scaling_efficiency),
        "Owens@64 MPI-Opt eff {:.2} (paper ≈0.90)",
        r.scaling_efficiency
    );
}

#[test]
fn headline_h6_fig9_efficiency_ordering() {
    let eff = |name: &str| {
        let ws = WorldSpec::new(presets::piz_daint(), models::by_name(name).unwrap(), 128);
        strategies::by_name("horovod-cray").unwrap().iteration(&ws).unwrap().scaling_efficiency
    };
    let (n, r, m) = (eff("nasnet"), eff("resnet50"), eff("mobilenet"));
    assert!(n > r && r > m, "H6 ordering: nasnet {n:.2} > resnet {r:.2} > mobilenet {m:.2}");
}

// ---------- config launcher × strategies ----------

#[test]
fn experiment_config_file_roundtrip_and_run() {
    let path = std::env::temp_dir().join(format!("mpi_dnn_it_{}.toml", std::process::id()));
    std::fs::write(
        &path,
        r#"
name = "it"
[workload]
cluster = "owens"
model = "mobilenet"
gpus = [1, 4]
[comm]
strategies = ["horovod-mpi-opt"]
"#,
    )
    .unwrap();
    let cfg = ExperimentConfig::from_file(&path).unwrap();
    std::fs::remove_file(&path).ok();
    assert_eq!(cfg.cluster.name, "Owens");
    for &g in &cfg.gpus {
        let ws = WorldSpec::new(cfg.cluster.clone(), cfg.model.clone(), g);
        let r = strategies::by_name(&cfg.strategies[0]).unwrap().iteration(&ws).unwrap();
        assert!(r.imgs_per_sec > 0.0);
    }
}

// ---------- comm correctness under strategy-like usage ----------

#[test]
fn allreduce_world_sizes_match_oracle_all_flavors() {
    use mpi_dnn_train::comm::allreduce::{max_abs_err, serial_oracle};
    let mut rng = mpi_dnn_train::util::prng::Rng::new(0xD15C);
    for flavor in [
        MpiFlavor::Mvapich2,
        MpiFlavor::Mvapich2GdrOpt,
        MpiFlavor::CrayMpich,
        MpiFlavor::Mpich,
    ] {
        for p in [2usize, 3, 7, 16, 24] {
            let w = MpiWorld::new(flavor, presets::ri2());
            let n = 1000 + p * 37;
            let mut bufs: Vec<Vec<f32>> = (0..p).map(|_| rng.f32_vec(n)).collect();
            let oracle = serial_oracle(&bufs);
            w.allreduce(&mut bufs);
            let e = max_abs_err(&bufs, &oracle);
            assert!(e < 1e-3, "{flavor:?} p={p}: err {e}");
        }
    }
}

#[test]
fn strategy_monotonicity_more_gpus_more_throughput() {
    // Sanity across every strategy: aggregate throughput must not shrink
    // when doubling GPUs (weak scaling).
    let model = models::by_name("resnet50").unwrap();
    for s in strategies::all_strategies() {
        if !s.available(&presets::ri2()) {
            continue;
        }
        let mut last = 0.0;
        for gpus in [1usize, 2, 4, 8, 16] {
            let ws = WorldSpec::new(presets::ri2(), model.clone(), gpus);
            let r = s.iteration(&ws).unwrap();
            assert!(
                r.imgs_per_sec >= last * 0.99,
                "{} throughput shrank at {gpus} GPUs: {} < {last}",
                s.name(),
                r.imgs_per_sec
            );
            last = r.imgs_per_sec;
        }
    }
}
