//! §Observability regression pins: the span tracer is a pure observer
//! (tracing on ≡ tracing off, bit for bit), its Chrome export is
//! deterministic and schema-valid, and the critical-path attribution
//! buckets account for the iteration time exactly.
//!
//! The headline configuration mirrors the acceptance scenario: traced
//! ResNet-50 Horovod-MPI-Opt at a non-trivial placement (2 GPUs/node)
//! with 2 comm streams under a straggler perturbation — the per-rank
//! graph path, stream lanes, shared node NIC/PCIe bundles and gates all
//! active at once.

use mpi_dnn_train::cluster::presets;
use mpi_dnn_train::comm::MpiFlavor;
use mpi_dnn_train::models::resnet;
use mpi_dnn_train::sim::trace::validate_chrome_json;
use mpi_dnn_train::sim::{SimTime, SpanKind, TraceGuard, TraceReport};
use mpi_dnn_train::strategies::{
    Horovod, IterationReport, PsStrategy, Scenario, Strategy, WorldSpec,
};

fn headline_ws() -> WorldSpec {
    let mut cluster = presets::ri2();
    cluster.gpus_per_node = 2;
    cluster.nic_rails = 1;
    WorldSpec::new(cluster, resnet::resnet50(), 8)
}

fn headline_sc() -> Scenario {
    Scenario { streams: 2, ..Scenario::straggler(1, 1.5) }
}

fn traced_headline() -> IterationReport {
    let _t = TraceGuard::new();
    Horovod::mpi(MpiFlavor::Mvapich2GdrOpt).iteration_in(&headline_ws(), &headline_sc()).unwrap()
}

fn trace_of(r: &IterationReport) -> &TraceReport {
    r.trace.as_deref().expect("traced run must attach a TraceReport")
}

fn path_sum(buckets: &[mpi_dnn_train::sim::PathBucket]) -> SimTime {
    SimTime(buckets.iter().map(|b| b.time.0).sum())
}

#[test]
fn tracing_off_is_bit_identical_to_tracing_on() {
    let ws = headline_ws();
    let sc = headline_sc();
    let h = Horovod::mpi(MpiFlavor::Mvapich2GdrOpt);
    let plain = h.iteration_in(&ws, &sc).unwrap();
    let traced = traced_headline();
    assert!(plain.trace.is_none(), "untraced run must not attach a trace");
    assert_eq!(plain.iter, traced.iter, "iteration time diverged under tracing");
    assert_eq!(plain.exposed_comm, traced.exposed_comm);
    assert_eq!(plain.engine_events, traced.engine_events, "event count diverged");
    assert_eq!(plain.resource_util, traced.resource_util, "resource ledger diverged");
}

#[test]
fn traced_runs_export_byte_identical_valid_chrome_json() {
    let a = traced_headline();
    let b = traced_headline();
    let (ta, tb) = (trace_of(&a), trace_of(&b));
    assert!(ta.spans > 0, "headline run must record spans");
    assert_eq!(ta.chrome_json, tb.chrome_json, "trace export must be deterministic");
    let events = validate_chrome_json(&ta.chrome_json).expect("export must validate");
    assert!(events > ta.spans, "metadata + spans expected, got {events} events");
}

#[test]
fn critical_path_buckets_sum_to_iteration_exactly() {
    let r = traced_headline();
    let t = trace_of(&r);
    assert_eq!(path_sum(&t.critical_path), t.iter, "critical path must account for iter");
    assert_eq!(t.iter, r.iter, "report and trace disagree on the iteration time");
    assert_eq!(path_sum(&t.comm_path), t.comm_end, "raw walk must account for comm end");
    // the straggled graph path's critical chain crosses wire transfers
    assert!(
        t.comm_path.iter().any(|b| b.label == "wire" && b.time > SimTime::ZERO),
        "expected a nonzero `wire` bucket, got {:?}",
        t.comm_path
    );
}

#[test]
fn wire_split_is_consistent_with_the_ledger_and_report() {
    let r = traced_headline();
    let t = trace_of(&r);
    // exposed + overlapped partitions total wire busy time (per span,
    // against the compute window) — cross-checked against the engine's
    // own service ledger for the wire rows
    let wire_busy: u64 = t
        .resources
        .iter()
        .filter(|row| row.kind == SpanKind::Wire)
        .map(|row| row.busy.0)
        .sum();
    assert_eq!(
        t.exposed_wire + t.overlapped_wire,
        SimTime(wire_busy),
        "wire split must partition the wire rows' busy time"
    );
    assert!(t.overlapped_wire > SimTime::ZERO, "streams=2 should overlap some wire time");
    // wire time exposed past the compute window implies the iteration
    // report exposes communication too
    if t.exposed_wire > SimTime::ZERO {
        assert!(r.exposed_comm > SimTime::ZERO, "exposed wire but no exposed comm");
    }
}

#[test]
fn resource_rows_carry_waits_and_histograms() {
    let r = traced_headline();
    let t = trace_of(&r);
    assert!(!t.resources.is_empty());
    for row in &t.resources {
        assert!(row.served > 0, "{}: report filters idle rows", row.name);
        let hist_total: u64 = row.wait_hist.iter().sum();
        assert_eq!(
            hist_total, row.served,
            "{}: every serve lands in exactly one wait bucket",
            row.name
        );
        assert_eq!(row.idle, t.iter.saturating_sub(row.busy), "{}: idle = iter - busy", row.name);
    }
    // shared node ports queue co-located ranks: some wait must show up
    let total_wait: u64 = t.resources.iter().map(|row| row.queue_wait.0).sum();
    assert!(total_wait > 0, "dense placement should produce queue waits");
    let render = t.render();
    assert!(render.contains("critical path"), "render mentions the path:\n{render}");
}

#[test]
fn serialized_path_and_ps_family_attach_summing_traces() {
    // neutral scenario at streams=1 rides the serialized CommOp replay;
    // the PS fan-in family runs its own graph path — both must attach a
    // trace whose buckets account for the iteration exactly
    let ws = headline_ws();
    for strat in [
        Box::new(Horovod::mpi(MpiFlavor::Mvapich2GdrOpt)) as Box<dyn Strategy>,
        Box::new(PsStrategy::grpc_mpi()),
    ] {
        let r = {
            let _t = TraceGuard::new();
            strat.iteration_in(&ws, &Scenario::default()).unwrap()
        };
        let t = trace_of(&r);
        assert!(t.spans > 0, "{}: no spans recorded", r.strategy);
        assert_eq!(
            path_sum(&t.critical_path),
            t.iter,
            "{}: critical path must sum to iter",
            r.strategy
        );
        assert_eq!(path_sum(&t.comm_path), t.comm_end, "{}: raw walk sum", r.strategy);
        validate_chrome_json(&t.chrome_json)
            .unwrap_or_else(|e| panic!("{}: invalid export: {e}", r.strategy));
    }
}
