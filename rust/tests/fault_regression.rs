//! §Robustness regression pins: the documented rank-crash scenario
//! recovers along detection → backoff → elastic rebuild with the
//! recovery intervals attributed on the traced critical path, the
//! whole faulted timeline (including the Chrome export) is
//! deterministic for a fixed plan, and transient faults never shrink
//! the world.
//!
//! The headline configuration mirrors the acceptance scenario and the
//! CI smoke step: MobileNet Horovod-MPI-Opt on ri2 at world 8 with
//! rank 3 crashing 1.5 ms into the iteration.

use mpi_dnn_train::cluster::presets;
use mpi_dnn_train::comm::MpiFlavor;
use mpi_dnn_train::models::mobilenet;
use mpi_dnn_train::sim::trace::validate_chrome_json;
use mpi_dnn_train::sim::{FaultPlan, SimTime, TraceGuard};
use mpi_dnn_train::strategies::{Horovod, IterationReport, Scenario, Strategy, WorldSpec};

fn crash_ws() -> WorldSpec {
    WorldSpec::new(presets::ri2(), mobilenet::mobilenet_v1(), 8)
}

fn crash_sc() -> Scenario {
    Scenario::with_fault(FaultPlan::crash(3, 1_500.0))
}

fn traced_crash() -> IterationReport {
    let _t = TraceGuard::new();
    Horovod::mpi(MpiFlavor::Mvapich2GdrOpt).iteration_in(&crash_ws(), &crash_sc()).unwrap()
}

fn path_time(buckets: &[mpi_dnn_train::sim::PathBucket], label: &str) -> SimTime {
    buckets.iter().find(|b| b.label == label).map(|b| b.time).unwrap_or(SimTime::ZERO)
}

fn path_sum(buckets: &[mpi_dnn_train::sim::PathBucket]) -> SimTime {
    SimTime(buckets.iter().map(|b| b.time.0).sum())
}

/// The documented acceptance scenario: an injected rank crash is
/// detected after the timeout, retried through the full backoff
/// budget, and recovered by an elastic rebuild over world − 1 — with
/// every interval pinned to the plan's knobs and the lost work and
/// goodput accounted in the report.
#[test]
fn rank_crash_recovers_elastically_with_pinned_intervals() {
    let ws = crash_ws();
    let h = Horovod::mpi(MpiFlavor::Mvapich2GdrOpt);
    let base = h.iteration_in(&ws, &Scenario::default()).unwrap();
    let r = traced_crash();
    let f = r.fault.expect("a crash plan must attach a FaultReport");
    let d = FaultPlan::default();
    assert_eq!(f.failed_at, SimTime::from_us(1_500.0));
    assert_eq!(f.detect, SimTime::from_us(d.detect_timeout_us));
    assert_eq!(
        f.recover,
        SimTime::from_us(d.detect_timeout_us + d.backoff_total_us() + d.rebuild_us),
        "recover = detect + exhausted backoff + rebuild"
    );
    assert_eq!(f.lost_work, SimTime::from_us(1_500.0), "no checkpoint: all progress lost");
    assert_eq!(f.retries, d.max_retries, "a dead peer exhausts the retry budget");
    assert_eq!(f.surviving_world, 7, "elastic shrink to world - 1");
    assert!(
        f.goodput_imgs_per_sec < base.imgs_per_sec,
        "goodput {} must trail the fault-free {} img/s",
        f.goodput_imgs_per_sec,
        base.imgs_per_sec
    );
    assert!(r.iter > SimTime::ZERO && r.iter >= f.recover, "recovery rides the iteration");
}

/// The traced crash run attributes the recovery on the critical path:
/// the retro-walk chains through the fault-detect / backoff / rebuild
/// marks with exactly the plan's durations, and the exact-sum
/// contracts of §Observability survive the fault cut.
#[test]
fn rank_crash_walks_recovery_marks_on_the_critical_path() {
    let r = traced_crash();
    let t = r.trace.as_deref().expect("traced run must attach a TraceReport");
    assert_eq!(path_sum(&t.critical_path), t.iter, "critical path must still sum to iter");
    assert_eq!(path_sum(&t.comm_path), t.comm_end, "raw walk must still sum to comm end");
    let d = FaultPlan::default();
    assert_eq!(
        path_time(&t.comm_path, "fault-detect"),
        SimTime::from_us(d.detect_timeout_us),
        "walk must cross the detection window: {:?}",
        t.comm_path
    );
    assert_eq!(path_time(&t.comm_path, "backoff"), SimTime::from_us(d.backoff_total_us()));
    assert_eq!(path_time(&t.comm_path, "rebuild"), SimTime::from_us(d.rebuild_us));
    let events = validate_chrome_json(&t.chrome_json).expect("faulted export must validate");
    assert!(events > 0);
    for mark in ["fault-detect", "backoff", "rebuild"] {
        assert!(t.chrome_json.contains(mark), "export must carry the `{mark}` recovery span");
    }
}

/// A fixed fault plan yields a fixed recovery: two traced runs agree on
/// the report, the fault ledger, and the Chrome export byte for byte.
#[test]
fn same_fault_plan_is_deterministic_including_trace_bytes() {
    let a = traced_crash();
    let b = traced_crash();
    assert_eq!(a.iter, b.iter, "faulted iteration time diverged");
    assert_eq!(a.engine_events, b.engine_events, "faulted event count diverged");
    assert_eq!(a.resource_util, b.resource_util, "faulted resource ledger diverged");
    assert_eq!(a.fault, b.fault, "fault ledger diverged");
    let (ta, tb) = (a.trace.as_deref().unwrap(), b.trace.as_deref().unwrap());
    assert_eq!(ta.chrome_json, tb.chrome_json, "faulted trace export must be deterministic");
}

/// Transient faults (a link flap) hold the port for the window but
/// never shrink the world or discard progress; the retry ladder stops
/// as soon as the cumulative backoff bridges the outage.
#[test]
fn link_flap_holds_the_port_without_shrinking_the_world() {
    let ws = crash_ws();
    let h = Horovod::mpi(MpiFlavor::Mvapich2GdrOpt);
    let base = h.iteration_in(&ws, &Scenario::default()).unwrap();
    let plan = FaultPlan::parse_spec("flap@200:n0.l0+300").unwrap();
    let r = h.iteration_in(&ws, &Scenario::with_fault(plan)).unwrap();
    let f = r.fault.expect("a flap plan must attach a FaultReport");
    assert_eq!(f.surviving_world, 8, "transient faults keep the full world");
    assert_eq!(f.lost_work, SimTime::ZERO, "no work is discarded on a flap");
    assert_eq!(f.failed_at, SimTime::from_us(200.0));
    // healthy no earlier than one detection window after onset
    assert_eq!(f.recover, SimTime::from_us(FaultPlan::default().detect_timeout_us));
    assert_eq!(f.retries, 2, "200 + 400 us of backoff bridges a 300 us outage");
    assert!(r.iter >= base.iter, "a held port can only delay the iteration");
}
