//! §Robustness chaos harness: randomized sustained-failure training
//! campaigns across every strategy family, pinned to five invariants —
//!
//!  1. **no deadlock** — every campaign completes under the engine's
//!     drain watchdog (`run_campaign` returns `Ok`, never hangs);
//!  2. **conservation across rebuilds** — the campaign clock is
//!     conserved exactly: productive + rollback + recovery + rejoin
//!     rebuild + checkpoint overhead == makespan, and every attempt is
//!     either committed or discarded, never lost;
//!  3. **goodput bound** — goodput never exceeds the best fault-free
//!     throughput of any visited world size;
//!  4. **same-seed bit-determinism** — re-running a config reproduces
//!     the `CampaignReport` byte-for-byte (trace included);
//!  5. **empty-campaign pin** — a fault-free, checkpoint-free campaign
//!     of N iterations is bit-identical to N plain iterations.

use mpi_dnn_train::cluster::presets;
use mpi_dnn_train::models::mobilenet;
use mpi_dnn_train::sim::trace::validate_chrome_json;
use mpi_dnn_train::sim::{run_campaign, CampaignReport, CampaignSpec, CheckpointPolicy, TraceGuard};
use mpi_dnn_train::strategies::{all_strategies, by_name, Scenario, Strategy, WorldSpec};
use mpi_dnn_train::util::prng::Rng;

fn ws_at(world: usize) -> WorldSpec {
    WorldSpec::new(presets::ri2(), mobilenet::mobilenet_v1(), world)
}

fn campaign_sc(spec: CampaignSpec) -> Scenario {
    let sc = Scenario { campaign: spec, ..Scenario::default() };
    sc.validate().expect("generated specs must be valid");
    sc
}

/// The five-invariant check every chaos campaign runs through.
fn assert_invariants(r: &CampaignReport, spec: &CampaignSpec, label: &str) {
    // invariant 2a: exact clock conservation across all buckets
    let buckets = r.productive.0
        + r.rollback_lost.0
        + r.recovery.0
        + r.rejoin_rebuild.0
        + r.checkpoint_overhead.0;
    assert_eq!(
        buckets, r.makespan.0,
        "{label}: clock not conserved (buckets {buckets} vs makespan {})",
        r.makespan.0
    );
    // invariant 2b: every attempt commits or is discarded, never lost
    assert_eq!(
        r.attempted,
        r.committed + r.discarded,
        "{label}: attempts leaked (attempted {} != committed {} + discarded {})",
        r.attempted,
        r.committed,
        r.discarded
    );
    assert_eq!(r.committed, spec.iters, "{label}: campaign must reach its target");
    // invariant 3: goodput never beats the best fault-free rate of any
    // visited world (PS throughput is not monotone in world size)
    let bound = r.fault_free_imgs_per_sec.max(r.degraded_imgs_per_sec);
    assert!(
        r.goodput_imgs_per_sec <= bound * (1.0 + 1e-9),
        "{label}: goodput {} exceeds the fault-free bound {bound}",
        r.goodput_imgs_per_sec
    );
    // structural sanity: the timeline opens at (0, world), rejoins never
    // outnumber crashes, and a fault-free campaign has neither
    assert_eq!(r.world_timeline.first(), Some(&(mpi_dnn_train::sim::SimTime::ZERO, r.world)));
    assert!(r.rejoins <= r.crashes, "{label}: {} rejoins > {} crashes", r.rejoins, r.crashes);
    if spec.mtbf_us == 0.0 {
        assert_eq!((r.crashes, r.rejoins, r.discarded), (0, 0, 0), "{label}: phantom faults");
    }
}

/// Invariant 5, pinned per strategy: an `iters`-long campaign with no
/// faults and no checkpoints is the same virtual time as `iters` plain
/// iterations — bit-identical, not approximately.
#[test]
fn empty_campaign_is_bit_identical_to_plain_iterations_for_every_strategy() {
    let iters = 23usize;
    let mut covered = 0;
    for s in all_strategies() {
        let ws = ws_at(8);
        let plain = match s.iteration(&ws) {
            Ok(r) => r,
            Err(_) => continue, // family unavailable on this fabric
        };
        let spec = CampaignSpec { iters, seed: 5, ..CampaignSpec::default() };
        let r = run_campaign(s.as_ref(), &ws, &campaign_sc(spec.clone())).unwrap();
        assert_invariants(&r, &spec, &s.name());
        assert_eq!(
            r.makespan.0,
            plain.iter.0 * iters as u64,
            "{}: empty campaign must be exactly {iters} plain iterations",
            s.name()
        );
        assert_eq!(r.productive, r.makespan, "{}: all time is productive", s.name());
        assert_eq!(r.checkpoints, 0);
        covered += 1;
    }
    assert!(covered >= 6, "only {covered} strategies ran the empty-campaign pin");
}

/// The chaos sweep: ≥100 randomized campaigns — world, strategy, length,
/// failure rate, checkpoint policy and repair time all drawn from a
/// seeded stream — each checked against the invariants, with every 10th
/// config re-run and compared byte-for-byte (invariant 4).
#[test]
fn randomized_campaigns_hold_the_chaos_invariants() {
    let strategies = all_strategies();
    let mut ran = 0usize;
    let mut config = 0usize;
    while ran < 110 {
        assert!(config < 400, "too many unavailable configs ({ran} of 110 ran)");
        let i = config;
        config += 1;
        let mut rng = Rng::new(0xC4A0_5EED ^ (i as u64).wrapping_mul(0x9E37_79B9));
        let s = &strategies[i % strategies.len()];
        let world = 4 + rng.next_below(5) as usize; // 4..=8
        let ws = ws_at(world);
        let base = match s.iteration(&ws) {
            Ok(r) => r,
            Err(_) => continue, // family unavailable at this point
        };
        let iter_us = base.iter.as_us();
        let iters = 10 + rng.next_below(31) as usize; // 10..=40
        let faulty = rng.next_below(4) != 0; // 3 in 4 campaigns see crashes
        let mtbf_us = if faulty {
            // system MTBF of 5–50 iterations, expressed per rank
            (5.0 + 45.0 * rng.next_f64()) * iter_us * world as f64
        } else {
            0.0
        };
        let repair_us = if faulty { (2.0 + 10.0 * rng.next_f64()) * iter_us } else { 0.0 };
        let policy = match rng.next_below(3) {
            0 => CheckpointPolicy::Off,
            1 => CheckpointPolicy::Fixed { period_us: (0.5 + 4.0 * rng.next_f64()) * iter_us },
            // young-daly needs an MTBF to optimize against
            _ if faulty => CheckpointPolicy::YoungDaly,
            _ => CheckpointPolicy::Fixed { period_us: (0.5 + 4.0 * rng.next_f64()) * iter_us },
        };
        let ckpt_cost_us = match policy {
            CheckpointPolicy::Off => 0.0,
            _ => (0.2 + 1.5 * rng.next_f64()) * iter_us,
        };
        let spec = CampaignSpec {
            iters,
            mtbf_us,
            seed: rng.next_u64(),
            policy,
            ckpt_cost_us,
            repair_us,
        };
        let label = format!("config {i} ({} world {world} iters {iters})", s.name());
        // invariant 1: completes under the drain watchdog
        let r = run_campaign(s.as_ref(), &ws, &campaign_sc(spec.clone()))
            .unwrap_or_else(|e| panic!("{label}: campaign failed: {e:#}"));
        assert_invariants(&r, &spec, &label);
        // invariant 4 on a sample: same config ⇒ byte-identical report
        if ran % 10 == 0 {
            let again = run_campaign(s.as_ref(), &ws, &campaign_sc(spec.clone())).unwrap();
            assert!(r == again, "{label}: same-seed re-run diverged");
        }
        ran += 1;
    }
}

/// Satellite 3: seeded fault-stream determinism per strategy family —
/// the same seed and config produce a byte-identical `CampaignReport`,
/// JSON export and Chrome trace across two traced runs.
#[test]
fn traced_campaigns_are_seed_deterministic_per_family() {
    for name in ["horovod-mpi-opt", "baidu", "grpc+mpi"] {
        let s = by_name(name).unwrap();
        let ws = ws_at(6);
        let spec = CampaignSpec {
            iters: 18,
            mtbf_us: 40_000.0,
            seed: 77,
            policy: CheckpointPolicy::YoungDaly,
            ckpt_cost_us: 400.0,
            repair_us: 6_000.0,
        };
        let run = || {
            let _t = TraceGuard::new();
            run_campaign(s.as_ref(), &ws, &campaign_sc(spec.clone())).unwrap()
        };
        let a = run();
        let b = run();
        assert!(a == b, "{name}: same-seed campaign reports diverged");
        assert_eq!(a.to_json().to_string(), b.to_json().to_string(), "{name}: JSON diverged");
        let ta = a.trace.as_ref().unwrap_or_else(|| panic!("{name}: no trace attached"));
        let tb = b.trace.as_ref().unwrap();
        assert_eq!(ta.chrome_json, tb.chrome_json, "{name}: Chrome exports diverged");
        validate_chrome_json(&ta.chrome_json)
            .unwrap_or_else(|e| panic!("{name}: invalid Chrome export: {e:#}"));
        assert_invariants(&a, &spec, name);
    }
}
