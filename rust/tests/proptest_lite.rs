//! Property-based tests over the core invariants, using a small seeded
//! generator kit (crates.io `proptest` is unavailable offline —
//! DESIGN.md §7).  Each property runs CASES random cases; failures print
//! the case seed so they reproduce exactly.

use mpi_dnn_train::cluster::presets;
use mpi_dnn_train::comm::allreduce::{
    max_abs_err, rhd_allreduce, ring_allreduce, serial_oracle, tree_allreduce, AllreduceCtx,
    ReducePlace, TransportMode,
};
use mpi_dnn_train::comm::fusion::{fuse, unfuse};
use mpi_dnn_train::comm::ptrcache::CacheMode;
use mpi_dnn_train::sim::{Engine, SimTime};
use mpi_dnn_train::util::json::Json;
use mpi_dnn_train::util::prng::Rng;

const CASES: u64 = 60;

fn ctx() -> AllreduceCtx {
    let c = presets::ri2();
    AllreduceCtx::new(
        c.fabric.clone(),
        c.gpu.clone(),
        TransportMode::Gdr,
        ReducePlace::Gpu,
        CacheMode::Intercept,
        c.driver_query_us,
    )
}

/// prop: every allreduce algorithm equals the serial oracle, for random
/// world sizes (incl. non-powers-of-two) and lengths (incl. 0, 1, odd).
#[test]
fn prop_allreduce_equals_oracle() {
    for case in 0..CASES {
        let mut rng = Rng::new(0xA001 + case);
        let p = 1 + rng.next_below(20) as usize;
        let n = rng.next_below(5000) as usize;
        let bufs: Vec<Vec<f32>> = (0..p).map(|_| rng.f32_vec(n)).collect();
        let oracle = serial_oracle(&bufs);
        for (name, algo) in [
            ("ring", ring_allreduce as fn(&mut [Vec<f32>], &mut AllreduceCtx) -> _),
            ("rhd", rhd_allreduce as fn(&mut [Vec<f32>], &mut AllreduceCtx) -> _),
            ("tree", tree_allreduce as fn(&mut [Vec<f32>], &mut AllreduceCtx) -> _),
        ] {
            let mut b = bufs.clone();
            let mut c = ctx();
            algo(&mut b, &mut c);
            let err = max_abs_err(&b, &oracle);
            assert!(
                err < 1e-3 * (p as f32).sqrt(),
                "case {case} ({name}, p={p}, n={n}): err {err}"
            );
        }
    }
}

/// prop: all ranks end with IDENTICAL buffers (not just near the oracle) —
/// the consistency property synchronous data parallelism relies on.
#[test]
fn prop_allreduce_ranks_agree_bitwise() {
    for case in 0..CASES {
        let mut rng = Rng::new(0xA002 + case);
        let p = 2 + rng.next_below(15) as usize;
        let n = 1 + rng.next_below(3000) as usize;
        let mut bufs: Vec<Vec<f32>> = (0..p).map(|_| rng.f32_vec(n)).collect();
        let mut c = ctx();
        rhd_allreduce(&mut bufs, &mut c);
        for r in 1..p {
            assert_eq!(bufs[0], bufs[r], "case {case}: rank {r} differs (p={p}, n={n})");
        }
    }
}

/// prop: ring and RHD move (near-)bandwidth-optimal wire bytes.
#[test]
fn prop_wire_bytes_bounded() {
    for case in 0..CASES {
        let mut rng = Rng::new(0xA003 + case);
        let p = 2 + rng.next_below(15) as usize;
        let n = 64 + rng.next_below(100_000) as usize;
        let mut bufs: Vec<Vec<f32>> = (0..p).map(|_| rng.f32_vec(n)).collect();
        let mut c = ctx();
        let r = ring_allreduce(&mut bufs, &mut c);
        let optimal = 2.0 * (n * 4) as f64 * (p as f64 - 1.0) / p as f64;
        assert!(
            (r.wire_bytes_per_rank as f64) < optimal * 1.2 + (p * 8) as f64,
            "case {case}: ring moved {} vs optimal {optimal}",
            r.wire_bytes_per_rank
        );
    }
}

/// prop: fusion pack/unpack is lossless for arbitrary tensor shapes and
/// thresholds, preserves order, and never exceeds the threshold unless a
/// single tensor does.
#[test]
fn prop_fusion_roundtrip() {
    for case in 0..CASES {
        let mut rng = Rng::new(0xF001 + case);
        let k = 1 + rng.next_below(40) as usize;
        let tensors: Vec<Vec<f32>> = (0..k)
            .map(|_| {
                let len = 1 + rng.next_below(2000) as usize;
                rng.f32_vec(len)
            })
            .collect();
        let refs: Vec<(usize, &[f32])> =
            tensors.iter().enumerate().map(|(i, t)| (i, t.as_slice())).collect();
        let threshold = 4 * (1 + rng.next_below(4000) as usize);
        let bufs = fuse(&refs, threshold);
        // lossless + ordered
        let mut seen = Vec::new();
        for b in &bufs {
            assert!(
                b.layout.len() == 1 || b.bytes() <= threshold,
                "case {case}: buffer over threshold with {} tensors",
                b.layout.len()
            );
            unfuse(b, |id, data| {
                assert_eq!(data, tensors[id].as_slice(), "case {case}: tensor {id} corrupted");
                seen.push(id);
            });
        }
        assert_eq!(seen, (0..k).collect::<Vec<_>>(), "case {case}: order broken");
    }
}

/// prop: the pointer cache (Intercept) always agrees with the driver,
/// under random alloc/free/query interleavings — while MpiLevel may not.
#[test]
fn prop_intercept_cache_coherent() {
    use mpi_dnn_train::comm::ptrcache::{BufKind, CudaDriverSim, PointerCache};
    for case in 0..CASES {
        let mut rng = Rng::new(0xC001 + case);
        let mut driver = CudaDriverSim::new(1.0);
        let mut cache = PointerCache::new(CacheMode::Intercept);
        let mut live: Vec<u64> = Vec::new();
        for _ in 0..200 {
            match rng.next_below(4) {
                0 => {
                    let kind =
                        if rng.next_below(2) == 0 { BufKind::Device } else { BufKind::Host };
                    let len = 1 + rng.next_below(4096);
                    let p = match kind {
                        BufKind::Device => driver.cu_malloc(len),
                        BufKind::Host => driver.host_malloc(len),
                    };
                    cache.on_malloc(p, kind);
                    live.push(p);
                }
                1 if !live.is_empty() => {
                    let i = rng.next_below(live.len() as u64) as usize;
                    let p = live.swap_remove(i);
                    driver.cu_free(p).unwrap();
                    cache.on_free(p);
                }
                _ if !live.is_empty() => {
                    let i = rng.next_below(live.len() as u64) as usize;
                    let p = live[i];
                    let truth = driver.query(p).0.unwrap();
                    let (got, _) = cache.resolve(p, &mut driver);
                    assert_eq!(got, truth, "case {case}: cache incoherent at {p:#x}");
                }
                _ => {}
            }
        }
    }
}

/// prop (§Perf): cached-template + overlay execution equals a freshly
/// built, mutated graph bit-for-bit — ring / RHD / tree and the PS
/// fan-in — under random straggler/hetero/jitter scenarios over random
/// worlds and step costs.  The materializer below replicates the old
/// in-place perturbation semantics (scale straggler ranks, scale hetero
/// ranks' GPU-side ops, insert jitter ops at node front) as the oracle.
#[test]
fn prop_overlay_replay_equals_fresh_perturbed_graphs() {
    use mpi_dnn_train::comm::allreduce::flp2;
    use mpi_dnn_train::comm::graph::{
        execute, ps_fanin_graph, rhd_graph, ring_graph, tree_graph, unmapped, CommGraph,
        GraphResources, GraphTemplate,
    };
    use mpi_dnn_train::comm::{CommOp, CostBreakdown, ResKind, StepCost};
    use mpi_dnn_train::strategies::Scenario;

    fn materialize(g: &CommGraph, sc: &Scenario, world: usize, salt: u64) -> CommGraph {
        let mut out = g.clone();
        if sc.straggler_ranks > 0 && sc.straggler_factor > 1.0 {
            for r in 0..sc.straggler_ranks.min(world) {
                for n in &mut out.nodes {
                    if n.rank == r {
                        for op in &mut n.ops {
                            op.us *= sc.straggler_factor;
                        }
                    }
                }
            }
        }
        if sc.hetero_ranks > 0 && sc.hetero_factor > 1.0 {
            for r in world.saturating_sub(sc.hetero_ranks)..world {
                for n in &mut out.nodes {
                    if n.rank == r {
                        for op in &mut n.ops {
                            if matches!(
                                op.kind,
                                ResKind::GpuReduce | ResKind::Launch | ResKind::Pcie
                            ) {
                                op.us *= sc.hetero_factor;
                            }
                        }
                    }
                }
            }
        }
        if sc.jitter_us > 0.0 {
            for n in &mut out.nodes {
                let j = sc.node_jitter_us(salt, n.rank, n.step);
                if j > 0.0 {
                    n.ops.insert(0, CommOp::fixed(ResKind::Sw, j));
                }
            }
        }
        out
    }

    for case in 0..30u64 {
        let mut rng = Rng::new(0xD001 + case);
        let p = 2 + rng.next_below(12) as usize; // 2..=13, incl. non-pow2
        let mk_cost = |rng: &mut Rng| CostBreakdown {
            wire_us: 1.0 + rng.next_f64() * 20.0,
            staging_us: rng.next_f64() * 4.0,
            reduce_us: rng.next_f64() * 3.0,
            driver_us: rng.next_f64(),
            launch_us: rng.next_f64(),
            sw_us: rng.next_f64() * 2.0,
        };
        let mk_steps = |n: usize, rng: &mut Rng| -> Vec<StepCost> {
            (0..n)
                .map(|_| StepCost { cost: mk_cost(rng), gpu_reduce: rng.next_below(2) == 0 })
                .collect()
        };
        let sc = Scenario {
            straggler_ranks: rng.next_below(3) as usize,
            straggler_factor: 1.0 + rng.next_f64() * 2.0,
            hetero_ranks: rng.next_below(3) as usize,
            hetero_factor: 1.0 + rng.next_f64() * 2.0,
            jitter_us: if rng.next_below(2) == 0 { 50.0 } else { 0.0 },
            seed: case,
            ..Scenario::default()
        };
        let salt = rng.next_below(5);

        let p2 = flp2(p);
        let rhd_count = if p > p2 { 2 } else { 0 } + 2 * p2.trailing_zeros() as usize;
        let tree_count = {
            let mut c = 0;
            let mut dist = 1;
            while dist < p {
                c += 1;
                dist *= 2;
            }
            let mut dist = p.next_power_of_two() / 2;
            while dist >= 1 {
                if (0..p).step_by(2 * dist).any(|s| s + dist < p) {
                    c += 1;
                }
                dist /= 2;
            }
            c
        };
        let graphs: Vec<(&str, CommGraph)> = vec![
            ("ring", ring_graph(p, &mk_steps(2 * (p - 1), &mut rng))),
            ("rhd", rhd_graph(p, &mk_steps(rhd_count, &mut rng))),
            ("tree", tree_graph(p, &mk_steps(tree_count, &mut rng))),
        ];
        for (name, g) in graphs {
            let oracle = materialize(&g, &sc, p, salt);
            let (end_f, fin_f) = {
                let mut e = Engine::new();
                let res = GraphResources::install(&mut e, p);
                let run = execute(&mut e, &oracle, res.mapper(), Box::new(|_| {}));
                let end = e.run();
                let fin = run.borrow().finish.clone();
                (end, fin)
            };
            let t = GraphTemplate::new(g);
            let ov = sc.overlay(p, salt);
            let (end_t, fin_t) = {
                let mut e = Engine::new();
                let res = GraphResources::install(&mut e, p);
                let run = t.execute(&mut e, res.mapper(), &ov, Box::new(|_| {}));
                let end = e.run();
                let fin = run.borrow().finish.clone();
                (end, fin)
            };
            assert_eq!(end_f, end_t, "case {case} {name} (p={p}): end diverged");
            assert_eq!(fin_f, fin_t, "case {case} {name} (p={p}): finishes diverged");
        }

        // PS fan-in with pinned NICs: identical resource-creation order in
        // both engines makes the pinned ids resolve identically
        let workers = 2 + rng.next_below(5) as usize;
        let server = rng.next_below(workers as u64) as usize;
        let wire = 2.0 + rng.next_f64() * 10.0;
        let mut ea = Engine::new();
        let (ni, no) = (ea.unit_resource(), ea.unit_resource());
        let (g, _pulls) = ps_fanin_graph(
            workers,
            server,
            |w| {
                vec![
                    CommOp::fixed(ResKind::Sw, 1.0 + w as f64),
                    CommOp::fixed(ResKind::Wire, wire).pinned(ni),
                ]
            },
            vec![CommOp::fixed(ResKind::CpuReduce, 3.0)],
            |w| {
                vec![
                    CommOp::fixed(ResKind::Wire, wire).pinned(no),
                    CommOp::fixed(ResKind::Sw, 0.5 + 0.5 * w as f64),
                ]
            },
        );
        let oracle = materialize(&g, &sc, workers, salt);
        let (end_f, fin_f) = {
            let run = execute(&mut ea, &oracle, unmapped(), Box::new(|_| {}));
            let end = ea.run();
            let fin = run.borrow().finish.clone();
            (end, fin)
        };
        let mut eb = Engine::new();
        let _nics = (eb.unit_resource(), eb.unit_resource());
        let t = GraphTemplate::new(g);
        let ov = sc.overlay(workers, salt);
        let (end_t, fin_t) = {
            let run = t.execute(&mut eb, unmapped(), &ov, Box::new(|_| {}));
            let end = eb.run();
            let fin = run.borrow().finish.clone();
            (end, fin)
        };
        assert_eq!(end_f, end_t, "case {case} ps (w={workers}): end diverged");
        assert_eq!(fin_f, fin_t, "case {case} ps (w={workers}): finishes diverged");
    }
}

/// prop (placement): with `gpus_per_node = 1` and `rails = 1`, the
/// placement-aware builders and resource bundles are **bit-identical**
/// to the historical per-rank path — ring / RHD / tree through the
/// placed builders (whatever the intra-hop factor, which must be inert
/// at one rank per node) and the PS fan-in on a trivially-placed
/// fabric — under random worlds, step costs, scenarios and overlays.
/// This is the seed-pin guarantee of the placement layer: every
/// pre-placement number survives verbatim on the paper's layouts.
#[test]
fn prop_trivial_placement_is_bit_identical_to_per_rank_bundles() {
    use mpi_dnn_train::cluster::Placement;
    use mpi_dnn_train::comm::allreduce::flp2;
    use mpi_dnn_train::comm::graph::{
        execute, ps_fanin_graph, rhd_graph, rhd_graph_placed, ring_graph, ring_graph_placed,
        tree_graph, tree_graph_placed, CommGraph, GraphResources, GraphTemplate,
    };
    use mpi_dnn_train::comm::{CommOp, CostBreakdown, ResKind, StepCost};
    use mpi_dnn_train::strategies::Scenario;

    for case in 0..30u64 {
        let mut rng = Rng::new(0xB001 + case);
        let p = 2 + rng.next_below(12) as usize; // 2..=13, incl. non-pow2
        let mk_cost = |rng: &mut Rng| CostBreakdown {
            wire_us: 1.0 + rng.next_f64() * 20.0,
            staging_us: rng.next_f64() * 4.0,
            reduce_us: rng.next_f64() * 3.0,
            driver_us: rng.next_f64(),
            launch_us: rng.next_f64(),
            sw_us: rng.next_f64() * 2.0,
        };
        let mk_steps = |n: usize, rng: &mut Rng| -> Vec<StepCost> {
            (0..n)
                .map(|_| StepCost { cost: mk_cost(rng), gpu_reduce: rng.next_below(2) == 0 })
                .collect()
        };
        let sc = Scenario {
            straggler_ranks: rng.next_below(3) as usize,
            straggler_factor: 1.0 + rng.next_f64() * 2.0,
            hetero_ranks: rng.next_below(3) as usize,
            hetero_factor: 1.0 + rng.next_f64() * 2.0,
            jitter_us: if rng.next_below(2) == 0 { 50.0 } else { 0.0 },
            seed: case,
            ..Scenario::default()
        };
        let salt = rng.next_below(5);
        // an arbitrary intra-hop factor: with one rank per node no hop
        // is ever intra, so it must not perturb a single bit
        let local = 0.1 + rng.next_f64() * 3.0;
        let trivial = Placement::one_per_node();

        let p2 = flp2(p);
        let rhd_count = if p > p2 { 2 } else { 0 } + 2 * p2.trailing_zeros() as usize;
        let tree_count = {
            let mut c = 0;
            let mut dist = 1;
            while dist < p {
                c += 1;
                dist *= 2;
            }
            let mut dist = p.next_power_of_two() / 2;
            while dist >= 1 {
                if (0..p).step_by(2 * dist).any(|s| s + dist < p) {
                    c += 1;
                }
                dist /= 2;
            }
            c
        };
        let ring_steps = mk_steps(2 * (p - 1), &mut rng);
        let rhd_steps = mk_steps(rhd_count, &mut rng);
        let tree_steps = mk_steps(tree_count, &mut rng);
        let pairs: Vec<(&str, CommGraph, CommGraph)> = vec![
            (
                "ring",
                ring_graph(p, &ring_steps),
                ring_graph_placed(p, &ring_steps, trivial, local),
            ),
            (
                "rhd",
                rhd_graph(p, &rhd_steps),
                rhd_graph_placed(p, &rhd_steps, trivial, local),
            ),
            (
                "tree",
                tree_graph(p, &tree_steps),
                tree_graph_placed(p, &tree_steps, trivial, local),
            ),
        ];
        let ov = sc.overlay(p, salt);
        for (name, legacy, placed) in pairs {
            // graphs must be structurally identical down to the f64 bits
            assert_eq!(legacy.len(), placed.len(), "case {case} {name}: node count");
            for (a, b) in legacy.nodes.iter().zip(&placed.nodes) {
                assert_eq!(a.rank, b.rank, "case {case} {name}");
                assert_eq!(a.step, b.step, "case {case} {name}");
                assert_eq!(a.deps, b.deps, "case {case} {name}");
                assert_eq!(a.ops.len(), b.ops.len(), "case {case} {name}");
                for (x, y) in a.ops.iter().zip(&b.ops) {
                    assert_eq!(x.kind, y.kind, "case {case} {name}: op kind");
                    assert_eq!(x.us.to_bits(), y.us.to_bits(), "case {case} {name}: op bits");
                }
            }
            // executions must agree bit-for-bit too: legacy graph on the
            // legacy per-rank install vs placed graph (as a cached
            // template under the scenario overlay) on the placed install
            let (end_l, fin_l) = {
                let mut e = Engine::new();
                let res = GraphResources::install(&mut e, p);
                let t = GraphTemplate::new(legacy);
                let run = t.execute(&mut e, res.mapper(), &ov, Box::new(|_| {}));
                let end = e.run();
                let fin = run.borrow().finish.clone();
                (end, fin)
            };
            let (end_p, fin_p) = {
                let mut e = Engine::new();
                let res = GraphResources::install_placed(&mut e, p, trivial);
                let t = GraphTemplate::new(placed);
                let run = t.execute(&mut e, res.mapper(), &ov, Box::new(|_| {}));
                let end = e.run();
                let fin = run.borrow().finish.clone();
                (end, fin)
            };
            assert_eq!(end_l, end_p, "case {case} {name}: end diverged");
            assert_eq!(fin_l, fin_p, "case {case} {name}: finishes diverged");
        }

        // PS fan-in: a trivially-placed fabric aliases every server onto
        // its own ports, so pinned-NIC graphs execute identically
        let workers = 2 + rng.next_below(5) as usize;
        let server = rng.next_below(workers as u64) as usize;
        let wire = 2.0 + rng.next_f64() * 10.0;
        let build = |ni, no| {
            ps_fanin_graph(
                workers,
                server,
                move |w| {
                    vec![
                        CommOp::fixed(ResKind::Sw, 1.0 + w as f64),
                        CommOp::fixed(ResKind::Wire, wire).pinned(ni),
                    ]
                },
                vec![CommOp::fixed(ResKind::CpuReduce, 3.0)],
                move |w| {
                    vec![
                        CommOp::fixed(ResKind::Wire, wire).pinned(no),
                        CommOp::fixed(ResKind::Sw, 0.5 + 0.5 * w as f64),
                    ]
                },
            )
        };
        use mpi_dnn_train::comm::graph::unmapped;
        use mpi_dnn_train::strategies::ps::PsFabric;
        let (end_l, fin_l) = {
            let mut e = Engine::new();
            let f = PsFabric::install(&mut e, workers);
            let (g, _) = build(f.ingress[server], f.egress[server]);
            let run = execute(&mut e, &g, unmapped(), Box::new(|_| {}));
            let end = e.run();
            let fin = run.borrow().finish.clone();
            (end, fin)
        };
        let (end_p, fin_p) = {
            let mut e = Engine::new();
            let f = PsFabric::install_placed(&mut e, workers, trivial);
            let (g, _) = build(f.ingress[server], f.egress[server]);
            let run = execute(&mut e, &g, unmapped(), Box::new(|_| {}));
            let end = e.run();
            let fin = run.borrow().finish.clone();
            (end, fin)
        };
        assert_eq!(end_l, end_p, "case {case} ps: end diverged");
        assert_eq!(fin_l, fin_p, "case {case} ps: finishes diverged");
    }
}

/// prop (§Overlap): scheduling a job's collectives on a single comm
/// stream lane is **bit-identical** to the retired comm-thread gate
/// path — random worlds, placements, step costs, overlays and release
/// times; ring / RHD / tree templates.  The gate oracle below replicates
/// the pre-overlap scheduling verbatim through the public engine API
/// (`at` → `acquire` → execute → `release`), so every serialized-era
/// figure pin is guaranteed to survive the stream-lane port at
/// `streams = 1`.
#[test]
fn prop_single_stream_equals_gated_path() {
    use std::cell::RefCell;
    use std::rc::Rc;
    use std::sync::Arc;

    use mpi_dnn_train::cluster::Placement;
    use mpi_dnn_train::comm::allreduce::flp2;
    use mpi_dnn_train::comm::graph::{
        rhd_graph_placed, ring_graph_placed, tree_graph_placed, GraphOverlay, GraphResMap,
        GraphResources, GraphTemplate,
    };
    use mpi_dnn_train::comm::{CostBreakdown, StepCost};
    use mpi_dnn_train::sim::{LaneDriver, LaneSetId};
    use mpi_dnn_train::strategies::Scenario;

    struct Lanes {
        items: Vec<(Arc<GraphTemplate>, GraphOverlay)>,
        map: GraphResMap,
    }
    impl LaneDriver for Lanes {
        fn launch(&self, e: &mut Engine, set: LaneSetId, job: u32) {
            let (t, ov) = &self.items[job as usize];
            t.execute_lane(e, self.map.clone(), ov, set, job);
        }
    }

    /// Every distinct resource of a bundle, for the stats comparison.
    fn all_resources(res: &GraphResources) -> Vec<mpi_dnn_train::sim::ResourceId> {
        let mut v = Vec::new();
        for ids in [&res.wire, &res.pcie, &res.gpu, &res.cpu, &res.driver, &res.launch, &res.sw] {
            v.extend(ids.iter().copied());
        }
        v
    }

    for case in 0..30u64 {
        let mut rng = Rng::new(0xA7_01 + case);
        let p = 2 + rng.next_below(10) as usize; // 2..=11, incl. non-pow2
        let gpn = 1 + rng.next_below(2) as usize; // 1 or 2 GPUs per node
        let rails = 1 + rng.next_below(gpn as u64) as usize;
        let place = Placement::new(gpn, rails);
        let local = 0.2 + rng.next_f64() * 2.0;
        let mk_cost = |rng: &mut Rng| CostBreakdown {
            wire_us: 1.0 + rng.next_f64() * 20.0,
            staging_us: rng.next_f64() * 4.0,
            reduce_us: rng.next_f64() * 3.0,
            driver_us: rng.next_f64(),
            launch_us: rng.next_f64(),
            sw_us: rng.next_f64() * 2.0,
        };
        let mk_steps = |n: usize, rng: &mut Rng| -> Vec<StepCost> {
            (0..n)
                .map(|_| StepCost { cost: mk_cost(rng), gpu_reduce: rng.next_below(2) == 0 })
                .collect()
        };
        let sc = Scenario {
            straggler_ranks: rng.next_below(3) as usize,
            straggler_factor: 1.0 + rng.next_f64() * 2.0,
            hetero_ranks: rng.next_below(3) as usize,
            hetero_factor: 1.0 + rng.next_f64() * 2.0,
            jitter_us: if rng.next_below(2) == 0 { 40.0 } else { 0.0 },
            seed: case,
            ..Scenario::default()
        };

        // 2..=5 collectives with random release times and per-collective
        // overlays, each a randomly chosen placed builder
        let count = 2 + rng.next_below(4) as usize;
        let p2 = flp2(p);
        let rhd_count = if p > p2 { 2 } else { 0 } + 2 * p2.trailing_zeros() as usize;
        let tree_count = {
            let mut c = 0;
            let mut dist = 1;
            while dist < p {
                c += 1;
                dist *= 2;
            }
            let mut dist = p.next_power_of_two() / 2;
            while dist >= 1 {
                if (0..p).step_by(2 * dist).any(|s| s + dist < p) {
                    c += 1;
                }
                dist /= 2;
            }
            c
        };
        let mut items: Vec<(SimTime, Arc<GraphTemplate>, GraphOverlay)> = Vec::new();
        for i in 0..count {
            let g = match rng.next_below(3) {
                0 => ring_graph_placed(p, &mk_steps(2 * (p - 1), &mut rng), place, local),
                1 => rhd_graph_placed(p, &mk_steps(rhd_count, &mut rng), place, local),
                _ => tree_graph_placed(p, &mk_steps(tree_count, &mut rng), place, local),
            };
            let ready = SimTime::from_us(rng.next_f64() * 150.0);
            items.push((ready, Arc::new(GraphTemplate::new(g)), sc.overlay(p, i as u64)));
        }

        // (a) the gate oracle: ready-time event → acquire → execute →
        // release, exactly the pre-overlap GraphJob scheduling
        let (end_g, comm_end_g, stats_g) = {
            let mut e = Engine::new();
            let res = GraphResources::install_placed(&mut e, p, place);
            let gate = e.gate();
            let comm_end = Rc::new(RefCell::new(SimTime::ZERO));
            for (ready, t, ov) in &items {
                let map = res.mapper();
                let t = t.clone();
                let ov = ov.clone();
                let ce = comm_end.clone();
                e.at(*ready, move |e| {
                    e.acquire(gate, move |e| {
                        t.execute(
                            e,
                            map,
                            &ov,
                            Box::new(move |e| {
                                *ce.borrow_mut() = e.now();
                                e.release(gate);
                            }),
                        );
                    });
                });
            }
            let end = e.run();
            let stats: Vec<_> =
                all_resources(&res).into_iter().map(|r| e.resource_stats(r)).collect();
            let gs = e.gate_stats(gate);
            assert_eq!(gs.served as usize, items.len(), "case {case}: oracle grants");
            (end, (*comm_end.borrow(), gs.busy), stats)
        };

        // (b) the stream-lane path at streams = 1
        let (end_l, comm_end_l, stats_l) = {
            let mut e = Engine::new();
            let res = GraphResources::install_placed(&mut e, p, place);
            let payload: Vec<_> =
                items.iter().map(|(_, t, ov)| (t.clone(), ov.clone())).collect();
            let set = e.lane_set(1, 1, Rc::new(Lanes { items: payload, map: res.mapper() }));
            for (i, (ready, _, _)) in items.iter().enumerate() {
                e.lane_submit(set, *ready, i as u32);
            }
            let end = e.run();
            assert_eq!(e.lane_completed(set), items.len(), "case {case}: lane completions");
            let stats: Vec<_> =
                all_resources(&res).into_iter().map(|r| e.resource_stats(r)).collect();
            let ls = e.lane_stats(set);
            assert_eq!(ls.served as usize, items.len(), "case {case}: lane launches");
            (end, (e.lane_last_done(set), ls.busy), stats)
        };

        assert_eq!(end_g, end_l, "case {case} (p={p}, gpn={gpn}): end diverged");
        assert_eq!(comm_end_g, comm_end_l, "case {case}: comm_end/busy diverged");
        assert_eq!(stats_g, stats_l, "case {case}: per-resource stats diverged");
    }
}

/// prop: the event engine is deterministic and clock-monotone for random
/// schedules.
#[test]
fn prop_engine_deterministic_and_monotone() {
    for case in 0..CASES {
        let run = |seed: u64| {
            let mut rng = Rng::new(seed);
            let mut e = Engine::new();
            let r = e.resource(5.0 + rng.next_f64() * 10.0, SimTime::from_us(rng.next_f64()));
            let log = std::rc::Rc::new(std::cell::RefCell::new(Vec::new()));
            for _ in 0..50 {
                let at = SimTime::from_us(rng.next_f64() * 100.0);
                let bytes = 1.0 + rng.next_f64() * 1000.0;
                let log = log.clone();
                e.at(at, move |e| {
                    let log = log.clone();
                    e.serve(r, bytes, move |e| log.borrow_mut().push(e.now()));
                });
            }
            e.run();
            let v = log.borrow().clone();
            v
        };
        let a = run(0xE001 + case);
        let b = run(0xE001 + case);
        assert_eq!(a, b, "case {case}: nondeterministic");
        for w in a.windows(2) {
            assert!(w[0] <= w[1], "case {case}: FIFO completions out of order");
        }
    }
}

/// prop: JSON parse∘print is identity on random JSON trees.
#[test]
fn prop_json_roundtrip() {
    fn gen(rng: &mut Rng, depth: usize) -> Json {
        match if depth == 0 { rng.next_below(4) } else { rng.next_below(6) } {
            0 => Json::Null,
            1 => Json::Bool(rng.next_below(2) == 0),
            2 => Json::Num((rng.next_below(2_000_001) as f64 - 1e6) / 8.0),
            3 => {
                let len = rng.next_below(12) as usize;
                Json::Str(
                    (0..len)
                        .map(|_| {
                            let c = rng.next_below(96) as u8 + 32;
                            c as char
                        })
                        .collect(),
                )
            }
            4 => Json::Arr((0..rng.next_below(5)).map(|_| gen(rng, depth - 1)).collect()),
            _ => Json::Obj(
                (0..rng.next_below(5))
                    .map(|i| (format!("k{i}"), gen(rng, depth - 1)))
                    .collect(),
            ),
        }
    }
    for case in 0..CASES {
        let mut rng = Rng::new(0x7501 + case);
        let j = gen(&mut rng, 3);
        let text = j.to_string();
        let back = Json::parse(&text).unwrap_or_else(|e| panic!("case {case}: {e}\n{text}"));
        assert_eq!(j, back, "case {case}: roundtrip mismatch\n{text}");
    }
}

/// prop: TOML-lite accepts what it prints conceptually — random flat
/// configs parse back to the same values.
#[test]
fn prop_toml_numbers_strings() {
    use mpi_dnn_train::config::parse_toml;
    for case in 0..CASES {
        let mut rng = Rng::new(0x70_01 + case);
        let i = rng.next_below(1_000_000) as i64 - 500_000;
        let f = (rng.next_below(1_000_000) as f64) / 997.0;
        let src = format!("a = {i}\nb = {f:.6}\nc = \"v{case}\"\nd = [{i}, {i}]\n");
        let doc = parse_toml(&src).unwrap_or_else(|e| panic!("case {case}: {e}"));
        assert_eq!(doc[""]["a"].as_int(), Some(i));
        assert!((doc[""]["b"].as_float().unwrap() - f).abs() < 1e-4);
        assert_eq!(doc[""]["c"].as_str(), Some(format!("v{case}").as_str()));
        assert_eq!(doc[""]["d"].as_array().unwrap().len(), 2);
    }
}

/// prop: PRNG uniformity bounds (chi-square-ish coarse check) and
/// Lemire bound correctness for random bounds.
#[test]
fn prop_prng_bounds() {
    for case in 0..CASES {
        let mut rng = Rng::new(0x9001 + case);
        let bound = 1 + rng.next_below(1000);
        let mut counts = vec![0u32; bound.min(16) as usize];
        for _ in 0..2000 {
            let v = rng.next_below(bound);
            assert!(v < bound, "case {case}: {v} >= {bound}");
            if (v as usize) < counts.len() {
                counts[v as usize] += 1;
            }
        }
        if bound <= 16 {
            let expect = 2000.0 / bound as f64;
            for (i, &c) in counts.iter().enumerate() {
                assert!(
                    (c as f64) > expect * 0.5 && (c as f64) < expect * 1.6,
                    "case {case}: bucket {i} count {c} vs expect {expect}"
                );
            }
        }
    }
}

/// prop: the calendar bucket queue pops in exactly the (time, seq)
/// order of a binary-heap oracle, across interleaved pushes and pops
/// with ties, dense bursts, and far-future jumps that route through the
/// overflow list (§Scale tie-break contract).
#[test]
fn prop_calendar_queue_matches_heap_oracle() {
    use mpi_dnn_train::sim::CalendarQueue;
    use std::cmp::Reverse;
    use std::collections::BinaryHeap;
    for case in 0..CASES {
        let mut rng = Rng::new(0xE101 + case);
        let mut cq: CalendarQueue<u64> = CalendarQueue::new();
        let mut heap: BinaryHeap<Reverse<(u64, u64)>> = BinaryHeap::new();
        let mut now = 0u64; // pops are monotone, like the engine clock
        let mut seq = 0u64;
        let rounds = 100 + rng.next_below(300);
        for _ in 0..rounds {
            let burst = 1 + rng.next_below(8);
            for _ in 0..burst {
                let at = match rng.next_below(10) {
                    0 => now,                               // tie at the active tick
                    1..=6 => now + rng.next_below(512),     // dense in-window
                    7 | 8 => now + rng.next_below(1 << 14), // mid-range
                    _ => now + rng.next_below(1 << 28),     // far future → overflow
                };
                cq.push(SimTime(at), seq, seq);
                heap.push(Reverse((at, seq)));
                seq += 1;
            }
            for _ in 0..rng.next_below(burst + 2) {
                match (cq.pop(), heap.pop()) {
                    (Some((at, s, item)), Some(Reverse((hat, hs)))) => {
                        assert_eq!((at.0, s, item), (hat, hs, hs), "case {case}: pop order");
                        now = at.0;
                    }
                    (None, None) => {}
                    (a, b) => panic!("case {case}: emptiness disagrees: {a:?} vs {b:?}"),
                }
            }
        }
        while let Some(Reverse((hat, hs))) = heap.pop() {
            let got = cq.pop().unwrap_or_else(|| panic!("case {case}: queue dry early"));
            assert_eq!(got, (SimTime(hat), hs, hs), "case {case}: drain order");
        }
        assert!(cq.pop().is_none(), "case {case}: queue has extra entries");
        assert!(cq.is_empty(), "case {case}: non-empty after drain");
    }
}

/// prop: the shared symmetric-rank plan replays bit-identical per-node
/// start/finish times to a freshly built per-rank template, for random
/// worlds (ring: any p; RHD: powers of two), random step costs, and
/// random overlays including per-rank skews and deterministic jitter
/// leads (§Scale rank-offset contract).
#[test]
fn prop_sym_plan_replays_full_template_bitwise() {
    use mpi_dnn_train::cluster::Placement;
    use mpi_dnn_train::comm::allreduce::Algo;
    use mpi_dnn_train::comm::graph::GraphRun;
    use mpi_dnn_train::comm::{
        allreduce_graph, sym_allreduce_plan, CostBreakdown, GraphOverlay, GraphResources,
        GraphTemplate, StepCost,
    };

    fn run_full(t: &GraphTemplate, ranks: usize, ov: &GraphOverlay) -> (SimTime, GraphRun) {
        let mut e = Engine::new();
        let res = GraphResources::install(&mut e, ranks);
        let run = t.execute(&mut e, res.mapper(), ov, Box::new(|_| {}));
        let end = e.run();
        let out = run.borrow().clone();
        (end, out)
    }

    for case in 0..CASES {
        let mut rng = Rng::new(0xE201 + case);
        let (algo, p) = if rng.next_below(2) == 0 {
            (Algo::Ring, 2 + rng.next_below(30) as usize)
        } else {
            (Algo::Rhd, 1usize << (1 + rng.next_below(5)))
        };
        let count = match algo {
            Algo::Ring => 2 * (p - 1),
            _ => 2 * p.trailing_zeros() as usize,
        };
        let mut steps = Vec::with_capacity(count);
        for _ in 0..count {
            steps.push(StepCost {
                cost: CostBreakdown {
                    wire_us: 0.5 + rng.next_f64() * 8.0,
                    staging_us: rng.next_f64() * 2.0,
                    reduce_us: rng.next_f64() * 3.0,
                    driver_us: rng.next_f64(),
                    launch_us: rng.next_f64() * 0.5,
                    sw_us: rng.next_f64() * 0.5,
                },
                gpu_reduce: rng.next_below(2) == 0,
            });
        }
        let mut ov = GraphOverlay::neutral();
        if rng.next_below(2) == 0 {
            ov.scale_global(1.0 + rng.next_f64());
        }
        if rng.next_below(2) == 0 {
            ov.scale_rank(p, rng.next_below(p as u64) as usize, 1.0 + rng.next_f64() * 2.0);
        }
        if rng.next_below(2) == 0 {
            ov.scale_rank_gpu(p, rng.next_below(p as u64) as usize, 1.0 + rng.next_f64());
        }
        if rng.next_below(2) == 0 {
            let salt = rng.next_below(1000);
            ov.set_lead(move |rank, step| {
                ((rank as u64 * 31 + step as u64 * 7 + salt) % 5) as f64 * 0.25
            });
        }

        let plan = sym_allreduce_plan(algo, p, &steps, Placement::one_per_node())
            .unwrap_or_else(|| panic!("case {case}: plan refused ({algo:?}, p={p})"));
        let full = GraphTemplate::new(allreduce_graph(algo, p, &steps));
        assert_eq!(plan.node_count(), full.graph().len(), "case {case}: node count");
        let (full_end, full_run) = run_full(&full, p, &ov);

        let mut e = Engine::new();
        let res = GraphResources::install(&mut e, p);
        let run = plan.execute(&mut e, &res, &ov, true, Box::new(|_| {})).expect("recording");
        let sym_end = e.run();
        let sym_run = run.borrow().clone();
        assert_eq!(sym_end, full_end, "case {case}: end time ({algo:?}, p={p})");
        assert_eq!(sym_run.start, full_run.start, "case {case}: node starts ({algo:?}, p={p})");
        assert_eq!(sym_run.finish, full_run.finish, "case {case}: node finishes");

        // shapes the shared plan must refuse: dense placements and
        // non-power-of-two RHD worlds
        assert!(sym_allreduce_plan(algo, p, &steps, Placement::new(2, 1)).is_none());
        assert!(sym_allreduce_plan(Algo::Rhd, 6, &steps, Placement::one_per_node()).is_none());
    }
}

/// prop: attaching the span tracer is observationally free — the traced
/// run's iteration report (times, event counts, per-resource ledger) is
/// bit-identical to the untraced run across random worlds, scenarios,
/// placements and stream counts (§Observability overhead contract; the
/// tracer is thread-local, so the guard scopes this test's thread only).
#[test]
fn prop_tracing_is_observationally_free() {
    use mpi_dnn_train::comm::MpiFlavor;
    use mpi_dnn_train::models::{mobilenet, resnet};
    use mpi_dnn_train::sim::TraceGuard;
    use mpi_dnn_train::strategies::{Horovod, Scenario, Strategy, WorldSpec};
    for case in 0u64..20 {
        let mut rng = Rng::new(0x0B5E + case);
        let world = 2 + rng.next_below(15) as usize;
        let mut cluster = presets::ri2();
        cluster.gpus_per_node = 1 + rng.next_below(2) as usize;
        cluster.nic_rails = 1;
        let model = if case % 2 == 0 { resnet::resnet50() } else { mobilenet::mobilenet_v1() };
        let sc = Scenario {
            straggler_ranks: rng.next_below(2) as usize,
            straggler_factor: 1.25 + rng.next_f64(),
            jitter_us: 50.0 * rng.next_below(2) as f64,
            seed: case,
            streams: 1 + rng.next_below(3) as usize,
            ..Scenario::default()
        };
        let ws = WorldSpec::new(cluster, model, world);
        let h = Horovod::mpi(MpiFlavor::Mvapich2GdrOpt);
        let plain = h.iteration_in(&ws, &sc).unwrap();
        let traced = {
            let _t = TraceGuard::new();
            h.iteration_in(&ws, &sc).unwrap()
        };
        assert_eq!(plain.iter, traced.iter, "case {case}: iteration time diverged");
        assert_eq!(plain.engine_events, traced.engine_events, "case {case}: events diverged");
        assert_eq!(plain.resource_util, traced.resource_util, "case {case}: ledger diverged");
        assert!(plain.trace.is_none(), "case {case}: untraced run attached a trace");
        assert!(traced.trace.is_some(), "case {case}: traced run attached none");
    }
}

/// prop (§Transports): the PS RPC window is monotone — a tighter
/// per-worker window never speeds the exchange up, and any finite
/// window is no faster than the unbounded reference — across random
/// worlds, models and all four transports.  (Shard releases are
/// readiness-ordered and the lane launcher issues in index order, so a
/// tighter cap can only delay every launch; this pins that argument.)
#[test]
fn prop_rpc_window_monotone() {
    use mpi_dnn_train::models::{mobilenet, resnet};
    use mpi_dnn_train::strategies::{PsStrategy, Scenario, Strategy, WorldSpec};
    for case in 0u64..12 {
        let mut rng = Rng::new(0x41D0 + case);
        let world = 3 + rng.next_below(10) as usize;
        let model = if case % 2 == 0 { mobilenet::mobilenet_v1() } else { resnet::resnet50() };
        let ps = match rng.next_below(4) {
            0 => PsStrategy::grpc(),
            1 => PsStrategy::grpc_mpi(),
            2 => PsStrategy::grpc_verbs(),
            _ => PsStrategy::rdma(),
        };
        let ws = WorldSpec::new(presets::ri2(), model, world);
        let base = ps.iteration(&ws).unwrap().iter;
        let lo = 1 + rng.next_below(4) as usize;
        let hi = lo + 1 + rng.next_below(8) as usize;
        let at = |w: usize| ps.iteration_in(&ws, &Scenario::windowed(w)).unwrap().iter;
        let (tight, loose) = (at(lo), at(hi));
        assert!(
            tight >= loose,
            "case {case} {} @{world}: window {lo} beat window {hi} ({tight} < {loose})",
            ps.name()
        );
        assert!(
            loose >= base,
            "case {case} {} @{world}: finite window {hi} beat unbounded ({loose} < {base})",
            ps.name()
        );
    }
}

/// prop (§Robustness): an *empty* fault plan is observationally free —
/// even with every recovery knob set to a non-default value, a plan
/// with no events takes the exact pre-fault code path in all three
/// strategy families, bit for bit, across random worlds, placements,
/// scenarios and stream counts (ARCHITECTURE.md §Faults empty-plan
/// guarantee).
#[test]
fn prop_empty_fault_plan_is_bit_identical() {
    use mpi_dnn_train::comm::MpiFlavor;
    use mpi_dnn_train::models::{mobilenet, resnet};
    use mpi_dnn_train::sim::FaultPlan;
    use mpi_dnn_train::strategies::{Baidu, Horovod, PsStrategy, Scenario, Strategy, WorldSpec};
    for case in 0u64..10 {
        let mut rng = Rng::new(0xFA17 + case);
        let world = 3 + rng.next_below(10) as usize;
        let mut cluster = presets::ri2();
        cluster.gpus_per_node = 1 + rng.next_below(2) as usize;
        cluster.nic_rails = 1;
        let model = if case % 2 == 0 { mobilenet::mobilenet_v1() } else { resnet::resnet50() };
        let sc = Scenario {
            straggler_ranks: rng.next_below(2) as usize,
            straggler_factor: 1.25 + rng.next_f64(),
            jitter_us: 40.0 * rng.next_below(2) as f64,
            seed: case,
            streams: 1 + rng.next_below(3) as usize,
            ..Scenario::default()
        };
        let knobbed = Scenario {
            fault: FaultPlan {
                events: Vec::new(),
                detect_timeout_us: 1.0 + rng.next_f64() * 5_000.0,
                backoff_base_us: 1.0 + rng.next_f64() * 500.0,
                backoff_factor: 1.0 + rng.next_f64(),
                max_retries: rng.next_below(16) as u32,
                rebuild_us: rng.next_f64() * 10_000.0,
                checkpoint_period_us: rng.next_f64() * 1_000.0,
            },
            ..sc.clone()
        };
        let ws = WorldSpec::new(cluster, model, world);
        let strategies: Vec<Box<dyn Strategy>> = vec![
            Box::new(Horovod::mpi(MpiFlavor::Mvapich2GdrOpt)),
            Box::new(Baidu::new()),
            Box::new(PsStrategy::grpc_mpi()),
        ];
        for s in strategies {
            let plain = s.iteration_in(&ws, &sc).unwrap();
            let inert = s.iteration_in(&ws, &knobbed).unwrap();
            let name = &plain.strategy;
            assert_eq!(plain.iter, inert.iter, "case {case} {name}: iter diverged");
            assert_eq!(plain.exposed_comm, inert.exposed_comm, "case {case} {name}: comm");
            assert_eq!(
                plain.imgs_per_sec.to_bits(),
                inert.imgs_per_sec.to_bits(),
                "case {case} {name}: throughput bits diverged"
            );
            assert_eq!(
                plain.engine_events, inert.engine_events,
                "case {case} {name}: event count diverged"
            );
            assert_eq!(
                plain.resource_util, inert.resource_util,
                "case {case} {name}: resource ledger diverged"
            );
            assert!(plain.fault.is_none() && inert.fault.is_none(), "case {case} {name}: fault");
        }
    }
}
